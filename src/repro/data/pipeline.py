"""Deterministic synthetic data pipeline (no external datasets in this
container), designed like a production loader:

* **step-addressable**: ``batch_at(step)`` is a pure function of (seed, step,
  host_id) — after a checkpoint restart the stream resumes exactly, and a
  re-shard after an elastic resize changes only the host partitioning, not
  the logical stream;
* **host-sharded**: each host materializes only its slice of the global
  batch (``host_id/num_hosts``);
* **prefetching**: a background thread keeps ``depth`` batches ahead.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    vocab: int = 512
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticLMDataset:
    """Markov-ish synthetic token stream with learnable structure (so loss
    actually decreases in the example drivers)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # fixed random bigram table: next ~ (cur * a + b) % vocab with noise
        self._a = int(rng.integers(3, 97)) | 1
        self._b = int(rng.integers(0, cfg.vocab))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 131 + cfg.host_id)
        b, s, v = cfg.host_batch, cfg.seq_len, cfg.vocab
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        noise = rng.random((b, s)) < 0.1
        rand = rng.integers(0, v, (b, s))
        for t in range(s):
            nxt = (toks[:, t] * self._a + self._b) % v
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class SyntheticImageDataset:
    """Random images + labels for the CNN pipelines."""

    def __init__(self, cfg: DataConfig, hw: int = 64, channels: int = 3,
                 classes: int = 10):
        self.cfg, self.hw, self.channels, self.classes = cfg, hw, channels, classes

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 131 + cfg.host_id)
        x = rng.normal(size=(cfg.host_batch, self.hw, self.hw,
                             self.channels)).astype(np.float32)
        y = rng.integers(0, self.classes, cfg.host_batch).astype(np.int32)
        return {"images": x, "labels": y}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def prefetch(it: Iterator[Any], depth: int = 2) -> Iterator[Any]:
    """Background-thread prefetching iterator."""
    q: queue.Queue = queue.Queue(depth)
    _END = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(_END)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is _END:
            return
        yield item
