"""Property + unit tests for the segmentation algorithms (paper Alg. 1)."""
import math

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.segmentation import (balanced_split, comp_split, dp_split,
                                     imbalance, max_segment, prof_split,
                                     segment_ranges, segment_sums,
                                     split_check)

arrays = st.lists(st.integers(min_value=0, max_value=10_000), min_size=1,
                  max_size=60)


@given(arrays, st.data())
@settings(max_examples=200, deadline=None)
def test_balanced_split_is_minimax_optimal(P, data):
    """Algorithm 1's binary search must equal the exact DP optimum."""
    s = data.draw(st.integers(min_value=1, max_value=len(P)))
    cuts = balanced_split(P, s)
    assert max_segment(P, cuts) == max_segment(P, dp_split(P, s))


@given(arrays, st.data())
@settings(max_examples=200, deadline=None)
def test_split_structure_invariants(P, data):
    s = data.draw(st.integers(min_value=1, max_value=len(P)))
    for fn in (balanced_split, comp_split):
        cuts = fn(P, s)
        assert len(cuts) == s - 1
        assert cuts == sorted(cuts)
        assert len(set(cuts)) == len(cuts)
        assert all(0 <= c < len(P) - 1 for c in cuts)
        sums = segment_sums(P, cuts)
        assert len(sums) == s
        assert sum(sums) == sum(P)
        ranges = segment_ranges(len(P), cuts)
        assert ranges[0][0] == 0 and ranges[-1][1] == len(P) - 1
        # contiguity
        for (a, b), (c, d) in zip(ranges[:-1], ranges[1:]):
            assert c == b + 1


@given(arrays, st.integers(min_value=0, max_value=100_000), st.data())
@settings(max_examples=200, deadline=None)
def test_split_check_greedy_consistency(P, bound, data):
    s = data.draw(st.integers(min_value=1, max_value=len(P)))
    ok, cuts = split_check(P, bound, s)
    if ok and bound >= max(P):
        # greedy found <= s segments, each within bound
        assert all(x <= bound for x in segment_sums(P, cuts))


def test_paper_synthetic_comp_vs_balanced():
    """Paper Table 4 vs Table 6: the compiler splits 5 layers 1-1-1-2 (tiny
    first segment, double last); balanced gives the small layer away."""
    small, big = 8_640, 921_600          # f=320 synthetic: 3f*9 and f^2*9
    P = [small, big, big, big, big]
    comp = comp_split(P, 4)
    assert segment_sums(P, comp) == [small, big, big, 2 * big]
    bal = balanced_split(P, 4)
    assert max(segment_sums(P, bal)) == small + big
    assert imbalance(P, bal) < imbalance(P, comp)


def test_prof_split_matches_balanced_for_minimax_cost():
    P = [5, 1, 9, 2, 2, 7, 3]
    cost = lambda cuts: max_segment(P, cuts)
    cuts = prof_split(P, 3, cost)
    assert max_segment(P, cuts) == max_segment(P, balanced_split(P, 3))


def test_prof_split_explodes_on_deep_models():
    """Paper §5.3: C(d-1, s-1) is infeasible for deep models."""
    d, s = 209, 6                        # ResNet101 example from the paper
    assert math.comb(d - 1, s - 1) > 3e9
    with pytest.raises(ValueError, match="infeasible"):
        prof_split([1] * d, s, lambda c: 0.0)


def test_validation_errors():
    with pytest.raises(ValueError):
        balanced_split([], 1)
    with pytest.raises(ValueError):
        balanced_split([1, 2], 3)
    with pytest.raises(ValueError):
        balanced_split([1, -2, 3], 2)
    with pytest.raises(ValueError):
        comp_split([1, 2, 3], 0)
