"""PlacementPlan tests: homogeneous no-replica plans are bit-identical to
the cut-list planner, the joint cuts+replicas DP strictly beats the best
non-replicated plan on pinned models, replicated executor runs preserve
submission order bit-for-bit, and plans JSON round-trip."""
import random

import pytest

from conftest import api_plan as plan
from conftest import api_plan_placement as plan_placement
from repro.core import (DeviceSpec, EdgeTPUModel, PipelineExecutor,
                        PlacementPlan, Topology, chain_graph)
from repro.core.segmentation import minimax_time_split, placement_split
from repro.core.topology import TopologyCostModel
from repro.models.cnn import REAL_CNNS

MIB = 2 ** 20


# ---------------------------------------------------------------------------
# bit-identical compatibility (acceptance criterion)
# ---------------------------------------------------------------------------
def test_homogeneous_noreplica_identical_to_opt_all_models():
    """On homogeneous devices with replicas forced to 1, PlacementPlan cuts
    and modeled stage times are bit-identical to strategy='opt' output for
    every Table-1 model."""
    for name, build in REAL_CNNS.items():
        g = build().to_layer_graph()
        m = EdgeTPUModel(g)
        s = max(2, min(4, g.depth - 1))
        base = plan(g, s, "opt", tpu_model=m)
        placed = plan_placement(g, Topology.homogeneous(s), strategy="opt",
                                replicate=False)
        assert placed.cuts == base.cuts, name
        assert placed.stage_times_s == base.stage_times_s, name
        assert placed.replica_counts == [1] * s, name
        # and the modeled times are exactly the device model's
        assert placed.stage_times_s == m.stage_times(base.cuts), name


def test_from_cuts_matches_plan_output():
    g = REAL_CNNS["MobileNet"]().to_layer_graph()
    m = EdgeTPUModel(g)
    p = plan(g, 3, "opt", tpu_model=m)
    q = PlacementPlan.from_cuts(g, p.cuts, strategy="opt", tpu_model=m)
    assert q.cuts == p.cuts
    assert q.stage_params == p.stage_params
    assert q.stage_layers == p.stage_layers
    assert q.stage_times_s == p.stage_times_s


# ---------------------------------------------------------------------------
# replication wins where the DP is pinned (acceptance criterion)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name,s_pin", [("MobileNet", 5),
                                        ("MobileNetV2", 3),
                                        ("ResNet50", 13)])
def test_replication_strictly_beats_best_nonreplicated(name, s_pin):
    """At a device budget of s+1 on a model whose s-stage plan is pinned by
    a dominant layer, the joint DP's modeled max stage time is strictly
    lower than the best (exact-DP) non-replicated s+1-stage plan."""
    g = REAL_CNNS[name]().to_layer_graph()
    m = EdgeTPUModel(g)
    budget = s_pin + 1
    cuts_nr = minimax_time_split(g.depth, budget, m.segment_time, exact=True)
    best_nonrep = max(m.stage_times(cuts_nr))
    pl = plan_placement(g, Topology.homogeneous(budget), replicate=True)
    assert pl.n_devices <= budget
    assert any(r > 1 for r in pl.replica_counts), name
    assert pl.max_stage_time_s < best_nonrep, name


def test_placement_split_unreplicated_never_worse_than_fixed_s():
    """max_replicas=1 placement over budget N = exact minimax over <= N
    stages: never worse than the exact N-stage DP."""
    g = REAL_CNNS["MobileNet"]().to_layer_graph()
    m = EdgeTPUModel(g)
    tcm = TopologyCostModel(g, Topology.homogeneous(4))
    cuts, reps = placement_split(g.depth, 4, tcm.placement_cost_fn(),
                                 max_replicas=1)
    assert reps == [1] * len(reps)
    exact = minimax_time_split(g.depth, 4, m.segment_time, exact=True)
    assert max(m.stage_times(cuts)) <= max(m.stage_times(exact)) + 1e-15


def test_replica_groups_respect_heterogeneous_boundaries():
    """Replicas may only span identical consecutive devices."""
    big = DeviceSpec(name="big", compute_scale=2.0)
    topo = Topology(devices=(DeviceSpec(), DeviceSpec(), big))
    assert topo.can_group(0, 2)
    assert not topo.can_group(1, 2)
    assert not topo.is_homogeneous
    g = chain_graph("toy", [(f"l{i}", 1000, 10_000, 64) for i in range(8)])
    pl = plan_placement(g, topo, replicate=True)
    # stages consume devices in topology order
    offset = 0
    for st in pl.stages:
        group = topo.devices[offset:offset + st.replicas]
        assert all(d == st.device for d in group)
        offset += st.replicas
    assert offset <= topo.n_devices


def test_heterogeneous_bigger_device_absorbs_more_depth():
    """A device with 2x compute should take a larger share of a uniform
    chain than its 1x peer."""
    layers = [(f"l{i}", 50_000, 5_000_000, 1024) for i in range(20)]
    g = chain_graph("uniform", layers)
    fast_first = Topology(devices=(DeviceSpec(name="fast", compute_scale=2.0),
                                   DeviceSpec()))
    pl = plan_placement(g, fast_first, replicate=False)
    lo, hi = pl.stages[0].depth_range
    assert (hi - lo + 1) > 10          # fast device takes more than half
    assert pl.stages[0].device.name == "fast"


# ---------------------------------------------------------------------------
# replicated executor (acceptance criterion: bit-for-bit output order)
# ---------------------------------------------------------------------------
def test_replicated_executor_outputs_bit_identical_to_unreplicated():
    rng = random.Random(0)

    def jitter(x):
        # thread-scheduling jitter: replicas finish out of order
        import time
        time.sleep(rng.random() * 0.003)
        return x * 2.0 + 1.0

    fns = [lambda x: x + 0.5, jitter, lambda x: x - 0.25]
    inputs = [i * 0.1 for i in range(40)]
    with PipelineExecutor(fns) as base:
        expect, _ = base.run_batch(inputs)
    with PipelineExecutor(fns, replicas=[1, 4, 1]) as rep:
        for _ in range(3):
            outs, _ = rep.run_batch(inputs)
            assert outs == expect       # same floats, same order


def test_replicated_executor_error_propagation_and_reuse():
    def boom(x):
        if x == 5:
            raise ValueError("bad item")
        return x

    ex = PipelineExecutor([boom, lambda x: x * 10], replicas=[3, 1])
    with pytest.raises(ValueError, match="bad item"):
        ex.run_batch(list(range(8)))
    outs, _ = ex.run_batch([1, 2, 3])   # stays usable, in order
    assert outs == [10, 20, 30]
    ex.stop()


def test_replicated_executor_busy_times_sum_over_replicas():
    from repro.core import simulated_stage
    ex = PipelineExecutor([simulated_stage(0.005)], replicas=[2])
    _, busy = ex.run_batch([0] * 10, collect_stage_times=True)
    assert busy is not None and len(busy) == 1
    assert busy[0] == pytest.approx(0.05, rel=0.5)
    ex.stop()


def test_replica_validation():
    with pytest.raises(ValueError):
        PipelineExecutor([lambda x: x], replicas=[1, 1])
    with pytest.raises(ValueError):
        PipelineExecutor([lambda x: x], replicas=[0])


# ---------------------------------------------------------------------------
# JSON (de)serialization
# ---------------------------------------------------------------------------
def test_plan_json_roundtrip_with_refinement():
    g = REAL_CNNS["ResNet50"]().to_layer_graph()
    p = plan(g, 4, "balanced")
    assert p.refinement is not None
    q = PlacementPlan.from_json(p.to_json())
    assert q.graph_name == p.graph_name
    assert q.strategy == p.strategy
    assert q.cuts == p.cuts
    assert q.stage_params == p.stage_params
    assert q.stage_layers == p.stage_layers
    assert q.stage_times_s == p.stage_times_s
    assert q.replica_counts == p.replica_counts
    assert q.refinement.converged == p.refinement.converged
    assert q.refinement.cuts == p.refinement.cuts


def test_plan_json_roundtrip_replicated_heterogeneous():
    g = chain_graph("toy", [(f"l{i}", 1000, 10_000, 64) for i in range(6)])
    pl = PlacementPlan.from_cuts(
        g, [1, 3], strategy="manual",
        devices=[DeviceSpec(), DeviceSpec(name="big", onchip_bytes=16 * MIB),
                 DeviceSpec()],
        replicas=[1, 2, 1])
    q = PlacementPlan.from_json(pl.to_json(indent=2))
    assert q.replica_counts == [1, 2, 1]
    assert q.stages[1].device.name == "big"
    assert q.stages[1].device.onchip_bytes == 16 * MIB
    assert q.effective_stage_times_s == pl.effective_stage_times_s
    assert q.n_devices == 4


def test_plan_json_rejects_foreign_documents():
    with pytest.raises(ValueError):
        PlacementPlan.from_json('{"format": "something/else"}')


def test_describe_annotates_devices_and_replicas():
    g = chain_graph("toy", [(f"l{i}", 1_000_000, 10_000, 64)
                            for i in range(6)])
    pl = PlacementPlan.from_cuts(g, [2], replicas=[2, 1],
                                 devices=[DeviceSpec(),
                                          DeviceSpec(name="tpu-v2",
                                                     compute_scale=2.0)])
    text = pl.describe()
    assert "x2" in text and "@tpu-v2" in text and "(3 devs)" in text


def test_effective_time_rule():
    """Replication divides everything except the weight-load term."""
    g = chain_graph("toy", [(f"l{i}", 100_000, 1_000_000, 2048)
                            for i in range(4)])
    pl = PlacementPlan.from_cuts(g, [1], replicas=[2, 1])
    st = pl.stages[0]
    assert st.time_s is not None and st.weight_load_s is not None
    expect = st.weight_load_s + (st.time_s - st.weight_load_s) / 2
    assert st.effective_time_s == expect
    assert pl.stages[1].effective_time_s == pl.stages[1].time_s
