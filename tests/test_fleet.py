"""Multi-tenant fleet tests (ISSUE 9): FleetSpec validation + JSON
round-trips, the pool-split solver (minimax DP vs a public brute force,
bounds, fixed splits, time-sliced fallback), the weighted-fair admission
router (deterministic DRR order, router-side deadlines, stop-drain), the
guarded autoscaler state machine (move -> guard -> commit / rollback,
donor floors), and the ``deploy_fleet`` lifecycle over live member
deployments.
"""
import json
import os
import time

import pytest

from repro.api import DeploymentSpec
from repro.core.pipeline import PipelineStopped
from repro.core.topology import DeviceSpec, Topology
from repro.fleet import (Fleet, FleetMemberSpec, FleetSpec, deploy_fleet,
                         plan_fleet)
from repro.fleet.placement import slo_norm
from repro.fleet.router import FleetRouter
from repro.fleet.scenario import FleetScenario, TrafficPhase
from repro.serving.server import DeadlineExceeded, Request, _RID

MODEL = "synthetic-cnn:8"


def member(name, *, model=MODEL, share=1.0, min_devices=1,
           max_devices=None, **spec_kw):
    return FleetMemberSpec(
        name=name, spec=DeploymentSpec(model=model, **spec_kw),
        share=share, min_devices=min_devices, max_devices=max_devices)


def identity_builders(spec):
    """Stage-function builders that pass payloads through unchanged."""
    def builder(pl):
        return [(lambda x: x) for _ in pl.stage_depth_ranges]
    return {n: builder for n in spec.member_names}


# ---------------------------------------------------------------------------
# FleetSpec validation + JSON round-trip
# ---------------------------------------------------------------------------
def test_member_spec_validation():
    with pytest.raises(ValueError, match="name"):
        FleetMemberSpec(name="", spec=DeploymentSpec(model=MODEL))
    with pytest.raises(ValueError, match="model ref"):
        FleetMemberSpec(name="a", spec=DeploymentSpec(stages=2))
    with pytest.raises(ValueError, match="share"):
        member("a", share=0.0)
    with pytest.raises(ValueError, match="min_devices"):
        member("a", min_devices=0)
    with pytest.raises(ValueError, match="max_devices"):
        member("a", min_devices=3, max_devices=2)
    # the pool-split solver owns the device shape
    for pin in ({"stages": 2}, {"device_budget": 2},
                {"topology": Topology.homogeneous(2)}):
        with pytest.raises(ValueError, match="pool-split"):
            FleetMemberSpec(name="a",
                            spec=DeploymentSpec(model=MODEL, **pin))


def test_fleet_spec_validation():
    with pytest.raises(ValueError, match="at least one member"):
        FleetSpec(members=(), device_budget=2)
    with pytest.raises(ValueError, match="duplicate"):
        FleetSpec(members=(member("a"), member("a")), device_budget=2)
    with pytest.raises(ValueError, match="exactly one"):
        FleetSpec(members=(member("a"),))
    with pytest.raises(ValueError, match="exactly one"):
        FleetSpec(members=(member("a"),), device_budget=2,
                  topology=Topology.homogeneous(2))
    with pytest.raises(ValueError, match="min_devices"):
        FleetSpec(members=(member("a", min_devices=3),
                           member("b", min_devices=2)), device_budget=4)
    # ...but a pool smaller than the member count is legal (time-sliced)
    FleetSpec(members=(member("a", min_devices=3), member("b")),
              device_budget=1)


def test_fleet_spec_json_roundtrip():
    fs = FleetSpec(
        members=(member("a", share=2.5, min_devices=1, max_devices=3,
                        slo_p95_ms=40.0, slo_throughput_rps=100.0),
                 member("b", model="synthetic-cnn:12")),
        device_budget=4, rebalance_cooldown_windows=3,
        rebalance_headroom=1.5)
    doc = fs.to_json()
    assert FleetSpec.from_json(doc) == fs
    json.loads(doc)                      # plain JSON, no repr smuggling

    # heterogeneous pool round-trips device-by-device
    topo = Topology(devices=(DeviceSpec(name="big", compute_scale=2.0),
                             DeviceSpec(name="small")), name="duo")
    fs2 = FleetSpec(members=(member("a"), member("b")), topology=topo)
    assert FleetSpec.from_json(fs2.to_json()) == fs2

    with pytest.raises(ValueError, match="fleet spec"):
        FleetSpec.from_json(json.dumps({"format": "something/else"}))


def test_example_fleet_json_parses():
    path = os.path.join(os.path.dirname(__file__), os.pardir,
                        "examples", "fleet.json")
    with open(path) as f:
        fs = FleetSpec.from_json(f.read())
    assert fs.pool().n_devices == 9
    assert fs.member_names == ("vision", "detect", "embed")
    assert FleetSpec.from_json(fs.to_json()) == fs


# ---------------------------------------------------------------------------
# SLO normalization
# ---------------------------------------------------------------------------
def test_slo_norm_terms():
    b = 0.010
    p95 = member("a", slo_p95_ms=20.0)
    assert slo_norm(p95, b) == pytest.approx(0.5)
    rps = member("a", slo_throughput_rps=300.0)
    assert slo_norm(rps, b) == pytest.approx(3.0)
    both = member("a", slo_p95_ms=20.0, slo_throughput_rps=300.0)
    assert slo_norm(both, b) == pytest.approx(3.0)    # max of terms
    none = member("a", share=4.0)                     # share fallback
    assert slo_norm(none, b) == pytest.approx(0.04)


# ---------------------------------------------------------------------------
# pool-split solver
# ---------------------------------------------------------------------------
def _skewed_fleet(pool=6, **fleet_kw):
    return FleetSpec(members=(
        member("heavy", model="synthetic-cnn:12", share=2.0,
               slo_p95_ms=40.0, slo_throughput_rps=9000.0),
        member("mid", slo_p95_ms=25.0, slo_throughput_rps=2500.0),
        member("light", slo_p95_ms=25.0, slo_throughput_rps=1200.0),
    ), device_budget=pool, **fleet_kw)


def test_plan_fleet_matches_public_brute_force():
    fs = _skewed_fleet(pool=6)
    solved = plan_fleet(fs)
    assert solved.mode == "partitioned"
    assert sum(solved.device_counts().values()) == 6
    # every split reachable through the public fixed_counts path
    best = None
    for kh in range(1, 5):
        for km in range(1, 5):
            kl = 6 - kh - km
            if kl < 1:
                continue
            priced = plan_fleet(fs, fixed_counts={"heavy": kh, "mid": km,
                                                  "light": kl})
            if best is None or priced.worst_norm < best:
                best = priced.worst_norm
    assert solved.worst_norm == pytest.approx(best)
    # the skew is real: the heavy member holds the most devices
    counts = solved.device_counts()
    assert counts["heavy"] == max(counts.values())


def test_plan_fleet_honors_device_bounds():
    fs = FleetSpec(members=(
        member("heavy", model="synthetic-cnn:12", share=2.0,
               slo_p95_ms=40.0, slo_throughput_rps=9000.0,
               max_devices=2),
        member("mid", slo_p95_ms=25.0, slo_throughput_rps=2500.0,
               min_devices=2),
        member("light", slo_p95_ms=25.0, slo_throughput_rps=1200.0),
    ), device_budget=6)
    counts = plan_fleet(fs).device_counts()
    assert counts["heavy"] <= 2
    assert counts["mid"] >= 2
    assert sum(counts.values()) == 6


def test_plan_fleet_infeasible_max_devices():
    fs = FleetSpec(members=(member("a", max_devices=2),
                            member("b", max_devices=2)),
                   device_budget=6)
    with pytest.raises(ValueError, match="no feasible pool split"):
        plan_fleet(fs)


def test_fixed_counts_validation():
    fs = _skewed_fleet(pool=6)
    with pytest.raises(ValueError, match="cover exactly"):
        plan_fleet(fs, fixed_counts={"heavy": 6})
    with pytest.raises(ValueError, match="sum"):
        plan_fleet(fs, fixed_counts={"heavy": 1, "mid": 1, "light": 1})
    with pytest.raises(ValueError, match=">= 1"):
        plan_fleet(fs, fixed_counts={"heavy": 5, "mid": 1, "light": 0})


def test_time_sliced_fallback():
    fs = FleetSpec(members=(member("a", share=3.0, slo_p95_ms=30.0),
                            member("b", share=1.0, slo_p95_ms=30.0)),
                   device_budget=1)
    p = plan_fleet(fs)
    assert p.mode == "time_sliced"
    a, b = p.allocation("a"), p.allocation("b")
    assert a.device_indices == b.device_indices == (0,)
    assert a.time_share == pytest.approx(0.75)
    assert b.time_share == pytest.approx(0.25)
    # co-residency inflates the effective bottleneck by 1/time_share
    assert a.bottleneck_s == pytest.approx(
        a.plan.max_stage_time_s / a.time_share)
    assert a.norm_cost == pytest.approx(
        slo_norm(fs.member("a"), a.bottleneck_s))


# ---------------------------------------------------------------------------
# admission router (deterministic stubs: no live servers needed)
# ---------------------------------------------------------------------------
class _StubServer:
    """Completes every dispatch synchronously, before the router can
    install its completion hook — exercising the completed-early path."""

    def __init__(self, log, name):
        self.log = log
        self.name = name
        self.stopped = False

    def submit(self, payload, deadline_s=None):
        req = Request(rid=next(_RID), payload=payload)
        req.result = payload
        req.t_done = time.perf_counter()
        req.event.set()
        self.log.append((self.name, payload))
        return req


def _stub_router(shares, log):
    servers = {n: (lambda s=_StubServer(log, n): s)() for n in shares}
    # suppliers, as the real fleet wires them
    return FleetRouter(servers={n: (lambda srv=s: srv)
                                for n, s in servers.items()},
                       shares=shares), servers


def test_router_validation():
    log = []
    with pytest.raises(ValueError, match="same"):
        FleetRouter(servers={"a": lambda: None}, shares={"b": 1.0})
    with pytest.raises(ValueError, match="at least one"):
        FleetRouter(servers={}, shares={})
    with pytest.raises(ValueError, match="share"):
        _stub_router({"a": 0.0}, log)


def test_router_unknown_member():
    router, _ = _stub_router({"a": 1.0}, [])
    with pytest.raises(KeyError, match="no fleet member"):
        router.submit("nope", 1)


def test_router_drr_respects_shares():
    """With a full backlog queued before dispatch starts, DRR order is
    deterministic: shares 2:1 dispatch in a a b sweeps."""
    log = []
    router, _ = _stub_router({"a": 2.0, "b": 1.0}, log)
    reqs = []
    for i in range(12):
        reqs.append(router.submit("a", ("a", i)))
    for i in range(6):
        reqs.append(router.submit("b", ("b", i)))
    with router:                       # start dispatching
        for r in reqs:
            assert r.event.wait(5.0)
    names = [n for n, _ in log]
    # every prefix stays near the 2:1 share ratio while both backlogged
    assert names[:6] == ["a", "a", "b", "a", "a", "b"]
    assert names.count("a") == 12 and names.count("b") == 6
    # per-member dispatch preserved submission order
    assert [p for n, p in log if n == "a"] == [("a", i) for i in range(12)]
    snap = router.snapshot()
    assert snap["members"]["a"]["completed"] == 12
    assert snap["members"]["b"]["completed"] == 6


def test_router_deadline_expires_in_queue():
    log = []
    router, _ = _stub_router({"a": 1.0}, log)
    done = []
    req = router.submit("a", 1, deadline_s=1e-4,
                        on_done=lambda r: done.append(r))
    time.sleep(0.01)                   # expire while still queued
    with router:
        assert req.event.wait(5.0)
    assert isinstance(req.error, DeadlineExceeded)
    assert req.error.where == "router"
    assert done == [req]
    assert router.snapshot()["members"]["a"]["expired_in_router"] == 1
    assert log == []                   # never reached the member server


def test_router_default_member_deadline():
    log = []
    servers = {"a": lambda: _StubServer(log, "a")}
    router = FleetRouter(servers=servers, shares={"a": 1.0},
                         deadlines_s={"a": 1e-4})
    req = router.submit("a", 1)
    time.sleep(0.01)
    with router:
        assert req.event.wait(5.0)
    assert isinstance(req.error, DeadlineExceeded)


def test_router_stop_drains_queue():
    log = []
    router, _ = _stub_router({"a": 1.0}, log)
    queued = [router.submit("a", i) for i in range(3)]
    router.stop()                      # never started: all still queued
    for r in queued:
        assert r.event.is_set()
        assert isinstance(r.error, PipelineStopped)
    # post-stop submissions complete immediately with the same error
    late = router.submit("a", 99)
    assert late.event.is_set()
    assert isinstance(late.error, PipelineStopped)


def test_router_routes_to_dead_member():
    router = FleetRouter(servers={"a": lambda: None}, shares={"a": 1.0})
    req = router.submit("a", 1)
    with router:
        assert req.event.wait(5.0)
    assert isinstance(req.error, PipelineStopped)


# ---------------------------------------------------------------------------
# autoscaler state machine (real deployments, injected observations)
# ---------------------------------------------------------------------------
def _two_member_fleet(**fleet_kw):
    spec = FleetSpec(members=(member("a", slo_p95_ms=20.0),
                              member("b", slo_p95_ms=20.0)),
                     device_budget=4, **fleet_kw)
    return spec, deploy_fleet(spec, stage_fn_builders=identity_builders(spec))


def test_autoscaler_steady_without_signal():
    _, fleet = _two_member_fleet()
    with fleet:
        auto = fleet.autoscaler
        assert auto is not None
        ev = auto.tick()
        assert ev["event"] == "steady"
        assert ev["norms"] == {}


def test_autoscaler_move_guard_commit():
    _, fleet = _two_member_fleet()
    with fleet:
        auto = fleet.autoscaler
        before = dict(auto.device_counts)
        auto._norm_ewma["a"] = 5.0          # "a" blows through its SLO
        ev = auto.tick()
        assert ev["event"] == "move"
        assert ev["move"] == {"from": "b", "to": "a"}
        after = auto.device_counts
        assert after["a"] == before["a"] + 1
        assert after["b"] == before["b"] - 1
        # the member deployments really were resized (hot-swap replan)
        assert fleet.deployments["a"].plan.n_devices == after["a"]
        assert fleet.deployments["b"].plan.n_devices == after["b"]
        assert auto.tick()["event"] == "guard"
        verdict = auto.tick()               # EWMA reset: no pressure left
        assert verdict["event"] == "commit"
        assert auto.committed_moves == 1
        assert auto.tick()["event"] == "cooldown"


def test_autoscaler_rollback_restores_split():
    _, fleet = _two_member_fleet()
    with fleet:
        auto = fleet.autoscaler
        before = dict(auto.device_counts)
        auto._norm_ewma["a"] = 5.0
        assert auto.tick()["event"] == "move"
        assert auto.tick()["event"] == "guard"
        # receiver got *worse* post-move: the guard must roll back
        auto._norm_ewma["a"] = 6.0
        auto._norm_ewma["b"] = 0.5
        verdict = auto.tick()
        assert verdict["event"] == "rollback"
        assert auto.device_counts == before
        assert fleet.deployments["a"].plan.n_devices == before["a"]
        assert auto.committed_moves == 0


def test_autoscaler_honors_donor_floor():
    spec = FleetSpec(members=(member("a", slo_p95_ms=20.0),
                              member("b", slo_p95_ms=20.0,
                                     min_devices=2)),
                     device_budget=4)
    fleet = deploy_fleet(spec, stage_fn_builders=identity_builders(spec))
    with fleet:
        auto = fleet.autoscaler
        counts = dict(auto.device_counts)
        auto._norm_ewma["a"] = 5.0
        if counts["b"] <= 2:               # b cannot shed below its floor
            assert auto.tick()["event"] == "steady"
            assert auto.device_counts == counts


# ---------------------------------------------------------------------------
# deploy_fleet lifecycle
# ---------------------------------------------------------------------------
def test_deploy_fleet_requires_builders_for_every_member():
    spec = FleetSpec(members=(member("a"), member("b")), device_budget=2)
    with pytest.raises(ValueError, match="missing members"):
        deploy_fleet(spec, stage_fn_builders={"a": lambda pl: []})


def test_fleet_submit_end_to_end_and_close():
    spec, fleet = _two_member_fleet()
    reqs = [fleet.submit("a", i) for i in range(4)]
    reqs += [fleet.submit("b", 10 + i) for i in range(4)]
    for r in reqs:
        assert r.event.wait(10.0)
        assert r.error is None
    assert [r.result for r in reqs] == [0, 1, 2, 3, 10, 11, 12, 13]
    snap = fleet.snapshot()
    assert set(snap["router"]["members"]) == {"a", "b"}
    assert set(snap["members"]) == {"a", "b"}
    assert sum(snap["device_counts"].values()) == 4
    fleet.close()
    fleet.close()                          # idempotent
    with pytest.raises(RuntimeError, match="closed"):
        fleet.submit("a", 0)
    for dep in fleet.deployments.values():
        assert dep.closed


def test_single_member_fleet_skips_autoscaler():
    spec = FleetSpec(members=(member("solo"),), device_budget=2)
    fleet = deploy_fleet(spec, stage_fn_builders=identity_builders(spec))
    with fleet:
        assert fleet.autoscaler is None
        req = fleet.submit("solo", 7)
        assert req.event.wait(10.0)
        assert req.result == 7


def test_time_sliced_fleet_serves():
    spec = FleetSpec(members=(member("a", share=3.0), member("b")),
                     device_budget=1)
    fleet = deploy_fleet(spec, stage_fn_builders=identity_builders(spec))
    with fleet:
        assert fleet.placement.mode == "time_sliced"
        assert fleet.autoscaler is None    # nothing to move
        reqs = [fleet.submit(n, i) for i, n in
                enumerate(["a", "b", "a", "b"])]
        for r in reqs:
            assert r.event.wait(10.0)
            assert r.error is None


# ---------------------------------------------------------------------------
# scenario driver (the bench/launch harness itself)
# ---------------------------------------------------------------------------
def test_scenario_drive_audit_clean():
    spec = FleetSpec(members=(member("a", share=2.0, slo_p95_ms=50.0),
                              member("b", slo_p95_ms=50.0)),
                     device_budget=4)
    sc = FleetScenario(spec, {"a": 1e-4, "b": 1e-4})
    fleet = sc.deploy()
    with fleet:
        metrics = sc.drive(fleet, [TrafficPhase(windows=2,
                                                rates={"a": 4, "b": 2})])
    audit = sc.audit()
    for name in ("a", "b"):
        assert audit[name]["lost"] == 0
        assert audit[name]["misordered"] == 0
        assert audit[name]["exited"] == audit[name]["submitted"]
    assert metrics["a"]["submitted"] == 8
    assert metrics["b"]["submitted"] == 4
    att = sc.attainment(metrics)
    assert set(att) == {"a", "b"}
    assert FleetScenario.worst(att) <= 1.0
