"""Self-healing serving: drift detection, guarded replans, overload
protection (ISSUE 8).  Controller tests drive ``tick()`` synchronously
against a scripted fake server, so every decision is deterministic."""
import random
import time

import pytest

from conftest import api_plan as plan
# the package re-exports deploy() under the submodule's name — go through
# importlib so monkeypatch targets the module, not the function
import importlib
deploy_mod = importlib.import_module("repro.api.deploy")
from repro.core.pipeline import PipelineExecutor, simulated_stage
from repro.core.placement import PlacementPlan
from repro.models.cnn import synthetic_cnn
from repro.profiling import LiveTraceBuilder, ProfileTrace
from repro.runtime import DriftDetector, DriftPolicy, SelfHealingController
from repro.serving import (DeadlineExceeded, Overloaded,
                           PipelinedModelServer)
from repro.api import DeploymentSpec


# ---------------------------------------------------------------------------
# drift detector
# ---------------------------------------------------------------------------
def _noisy_stream(seed, n, base, skew_stage=None, skew=1.0):
    rnd = random.Random(seed)
    out = []
    for _ in range(n):
        obs = [b * (1 + 0.05 * rnd.random()) for b in base]
        if skew_stage is not None:
            obs[skew_stage] *= skew
        out.append(obs)
    return out


def test_drift_detector_is_deterministic():
    """Identical seeded streams -> identical drift values and triggers."""
    pol = DriftPolicy(drift_threshold=0.4, hysteresis=3)
    modeled = [0.01, 0.01, 0.01]
    stream = _noisy_stream(7, 12, modeled, skew_stage=0, skew=4.0)

    def run():
        det = DriftDetector(pol)
        return [(round(det.observe(modeled, obs), 9), det.triggered)
                for obs in stream]

    a, b = run(), run()
    assert a == b
    assert a[-1][1]                        # sustained skew does trigger


def test_drift_is_shape_based_not_scale_based():
    """A uniformly slower device (same *shape*) must not trigger: the
    same cuts stay optimal, replanning would thrash for nothing."""
    pol = DriftPolicy(drift_threshold=0.2, hysteresis=2)
    det = DriftDetector(pol)
    modeled = [0.01, 0.02, 0.03]
    for _ in range(10):
        det.observe(modeled, [5 * t for t in modeled])   # 5x everywhere
        assert not det.triggered
    assert det.last_drift == pytest.approx(0.0, abs=1e-9)


def test_hysteresis_oscillating_load_does_not_thrash():
    """Alternating drifty/calm windows never reach ``hysteresis``
    consecutive exceedances -> no trigger."""
    pol = DriftPolicy(drift_threshold=0.3, hysteresis=3, ewma_alpha=1.0)
    det = DriftDetector(pol)
    modeled = [0.01, 0.01]
    for i in range(20):
        obs = [0.01, 0.05] if i % 2 == 0 else [0.01, 0.01]
        det.observe(modeled, obs)
        assert not det.triggered


def test_detector_rebase_on_shape_change():
    pol = DriftPolicy(drift_threshold=0.1, hysteresis=1)
    det = DriftDetector(pol)
    det.observe([0.01, 0.01], [0.01, 0.05])
    assert det.triggered
    # stage count changed (a replan landed): streak must not carry over
    det.observe([0.01, 0.01, 0.01], [0.01, 0.01])
    assert not det.triggered


# ---------------------------------------------------------------------------
# live trace builder
# ---------------------------------------------------------------------------
def test_live_trace_builder_apportions_and_round_trips():
    g = synthetic_cnn(600).to_layer_graph()
    ltb = LiveTraceBuilder(g)
    mid = g.depth // 2
    ranges = [(0, mid - 1), (mid, g.depth - 1)]
    n = ltb.observe(ranges, [0.010, 0.030], [5, 5])
    assert n == g.depth and ltb.coverage() == 1.0
    tr = ltb.trace()
    # apportioning preserves each stage's observed total exactly
    st = tr.stage_times(ranges)
    assert st is not None
    assert st[0] == pytest.approx(0.010, rel=1e-9)
    assert st[1] == pytest.approx(0.030, rel=1e-9)
    # the emitted artifact is a standard versioned trace
    again = ProfileTrace.from_json(tr.to_json())
    assert again.depth_time_map() == tr.depth_time_map()
    # both cost-source kinds wrap it
    assert ltb.cost_source("trace").trace is not None
    assert ltb.cost_source("calibrated").trace is not None
    with pytest.raises(ValueError):
        ltb.cost_source("bogus")


def test_live_trace_builder_skips_empty_stages_and_ewma_smooths():
    g = synthetic_cnn(600).to_layer_graph()
    ltb = LiveTraceBuilder(g, alpha=0.5)
    mid = g.depth // 2
    ranges = [(0, mid - 1), (mid, g.depth - 1)]
    # second stage saw no items: only the first stage's depths update
    n = ltb.observe(ranges, [0.010, 0.0], [5, 0])
    assert 0 < n < g.depth
    assert ltb.coverage() == pytest.approx(mid / g.depth)
    t1 = ltb.depth_time(0)
    ltb.observe(ranges, [0.020, 0.0], [5, 0])     # 2x slower window
    t2 = ltb.depth_time(0)
    assert t1 < t2 < 2 * t1                       # smoothed, not jumped


# ---------------------------------------------------------------------------
# controller: guarded replan state machine (scripted fake server)
# ---------------------------------------------------------------------------
class _FakeServer:
    """Interface double for PipelinedModelServer: scripted snapshots,
    recorded reconfigures."""

    def __init__(self, pl, snaps):
        self.plan = pl
        self.stage_fns = [lambda x: x] * pl.n_stages
        self._snaps = list(snaps)
        self.reconfigures = []

    def push(self, snap):
        self._snaps.append(snap)

    def snapshot(self):
        return self._snaps.pop(0)

    def reconfigure(self, pl, fns, drain_timeout=30.0):
        self.reconfigures.append(pl)
        self.plan = pl
        self.stage_fns = list(fns)


def _snap_for(pl, skew_stage=None, skew=1.0):
    base = [float(t) for t in pl.stage_times_s]
    if skew_stage is not None:
        base[skew_stage] *= skew
    return {"stage_time_per_req_s": base,
            "stage_items": [10] * pl.n_stages}


def _controller(srv, g, policy, builder=None, spec=None):
    return SelfHealingController(
        srv, spec or DeploymentSpec(stages=srv.plan.n_stages), g,
        builder or (lambda pl: [lambda x: x] * pl.n_stages),
        policy=policy, canary_payloads=[1, 2])


def test_controller_commit_via_canary(monkeypatch):
    g = synthetic_cnn(600).to_layer_graph()
    incumbent = plan(g, 3)
    candidate = PlacementPlan.from_cuts(g, [1, 3])
    assert candidate.cuts != incumbent.cuts   # distinct target plan
    monkeypatch.setattr(deploy_mod, "plan", lambda *a, **k: candidate)
    pol = DriftPolicy(drift_threshold=0.3, hysteresis=2,
                      cooldown_windows=2, ewma_alpha=1.0)
    srv = _FakeServer(incumbent,
                      [_snap_for(incumbent, 0, 8.0) for _ in range(3)])
    ctl = _controller(srv, g, pol)
    ctl.tick()
    assert srv.reconfigures == []           # hysteresis: one window is
    ctl.tick()                              # not drift; two is
    assert srv.reconfigures == [candidate]
    assert ctl.commits == 1 and ctl.state == "cooldown"
    assert ctl.prior is not None and ctl.prior[0] is incumbent
    # cooldown suppresses immediate re-trigger even under drift
    srv.push(_snap_for(candidate, 0, 8.0))
    ctl.tick()
    assert ctl.commits == 1
    ev = [e for e in ctl.events if e["kind"] == "commit"]
    assert len(ev) == 1 and ev[0]["cuts"] == list(candidate.cuts)


def test_controller_rollback_backoff_degrade_and_rearm(monkeypatch):
    """A candidate that fails mid-validation never replaces the
    incumbent: rollback -> seeded backoff -> bounded retries -> degraded
    -> re-arm once drift subsides."""
    g = synthetic_cnn(600).to_layer_graph()
    incumbent = plan(g, 3)
    candidate = PlacementPlan.from_cuts(g, [1, 3])
    monkeypatch.setattr(deploy_mod, "plan", lambda *a, **k: candidate)

    def exploding_builder(pl):
        if pl.cuts == candidate.cuts:        # only the canary build dies
            def boom(x):
                raise RuntimeError("candidate replica crashed")
            return [boom] * pl.n_stages
        return [lambda x: x] * pl.n_stages

    pol = DriftPolicy(drift_threshold=0.3, hysteresis=1,
                      cooldown_windows=0, ewma_alpha=1.0,
                      max_canary_retries=1, backoff_base_windows=1,
                      backoff_max_windows=4, backoff_seed=0)
    srv = _FakeServer(incumbent, [])
    ctl = _controller(srv, g, pol, builder=exploding_builder)
    for _ in range(12):
        srv.push(_snap_for(incumbent, 0, 8.0))
        ctl.tick()
        if ctl.state == "degraded":
            break
    assert ctl.state == "degraded"
    assert srv.reconfigures == []           # incumbent never displaced
    assert ctl.rollbacks >= 2               # first failure + the retry
    kinds = [e["kind"] for e in ctl.events]
    assert "rollback" in kinds and "degraded" in kinds
    # drift subsides -> the loop re-arms
    srv.push(_snap_for(incumbent))
    ctl.tick()
    assert ctl.state == "steady"
    assert any(e["kind"] == "rearmed" for e in ctl.events)


def test_controller_backoff_is_seed_deterministic(monkeypatch):
    g = synthetic_cnn(600).to_layer_graph()
    incumbent = plan(g, 3)
    candidate = PlacementPlan.from_cuts(g, [1, 3])
    monkeypatch.setattr(deploy_mod, "plan", lambda *a, **k: candidate)

    def run():
        pol = DriftPolicy(drift_threshold=0.3, hysteresis=1,
                          cooldown_windows=0, ewma_alpha=1.0,
                          max_canary_retries=5, backoff_base_windows=1,
                          backoff_seed=3)
        srv = _FakeServer(incumbent, [])
        ctl = _controller(
            srv, g, pol,
            builder=lambda pl: [lambda x: (_ for _ in ()).throw(
                RuntimeError("no"))] * pl.n_stages)
        states = []
        for _ in range(10):
            srv.push(_snap_for(incumbent, 0, 8.0))
            ctl.tick()
            states.append((ctl.state, ctl._backoff, ctl._retries))
        return states

    assert run() == run()


def test_controller_noop_when_live_plan_endorses_incumbent(monkeypatch):
    g = synthetic_cnn(600).to_layer_graph()
    incumbent = plan(g, 3)
    monkeypatch.setattr(deploy_mod, "plan", lambda *a, **k: incumbent)
    pol = DriftPolicy(drift_threshold=0.3, hysteresis=1,
                      cooldown_windows=2, ewma_alpha=1.0)
    srv = _FakeServer(incumbent, [_snap_for(incumbent, 0, 8.0)])
    ctl = _controller(srv, g, pol)
    ctl.tick()
    assert srv.reconfigures == [] and ctl.commits == 0
    assert ctl.state == "cooldown"
    assert any(e["kind"] == "noop" for e in ctl.events)


def test_controller_real_replan_path_runs():
    """Unmocked end-to-end tick: real plan() against the live calibrated
    source.  Whatever the planner decides (commit or noop), the loop must
    land in cooldown without touching executor threads."""
    g = synthetic_cnn(600).to_layer_graph()
    incumbent = plan(g, 3)
    pol = DriftPolicy(drift_threshold=0.3, hysteresis=1,
                      cooldown_windows=1, ewma_alpha=1.0)
    srv = _FakeServer(incumbent, [_snap_for(incumbent, 0, 6.0)])
    ctl = _controller(srv, g, pol)
    drift = ctl.tick()
    assert drift is not None and drift > pol.drift_threshold
    assert ctl.state == "cooldown"
    assert ctl.replans == 1


# ---------------------------------------------------------------------------
# server overload protection
# ---------------------------------------------------------------------------
def _two_stage_server(stage_s=0.0, **kw):
    g = synthetic_cnn(600).to_layer_graph()
    pl = plan(g, 2)
    fns = [simulated_stage(stage_s) if stage_s else (lambda x: x),
           lambda x: x]
    return PipelinedModelServer(pl, fns, max_batch=4, max_wait_s=0.005,
                                **kw)


def test_deadline_exceeded_at_admission():
    srv = _two_stage_server()
    with srv:
        req = srv.submit(1, deadline_s=0.005)
        time.sleep(0.05)                   # expires while unadmitted
        srv.start()
        assert req.event.wait(5)
        assert isinstance(req.error, DeadlineExceeded)
        assert req.error.where == "admission"
    assert srv.stats["deadline_exceeded"] == 1


def test_deadline_exceeded_at_merge_exit():
    srv = _two_stage_server(stage_s=0.06, deadline_s=0.01)
    with srv:
        srv.start()
        req = srv.submit(1)                # server default budget applies
        assert req.event.wait(5)           # bounded: never silently stuck
        assert isinstance(req.error, DeadlineExceeded)
        assert req.error.where == "merge"
        assert req.result is None


def test_deadline_none_is_unbounded_compat():
    srv = _two_stage_server(stage_s=0.01)
    with srv:
        srv.start()
        req = srv.submit(7)
        assert req.event.wait(5)
        assert req.error is None and req.result == 7


def test_overload_shedding_and_backoff_hint():
    srv = _two_stage_server(stage_s=0.05, deadline_s=0.04,
                            shed_policy="deadline")
    with srv:
        srv.start()
        # prime the pace estimate way past any budget: next admission
        # with work in flight must shed
        srv._pace_ewma = 10.0
        first = srv.submit(1, deadline_s=10.0)   # occupies the pipeline
        time.sleep(0.01)                         # let it admit
        shed = srv.submit(2)
        assert shed.event.wait(5)
        assert isinstance(shed.error, Overloaded)
        assert shed.error.retry_after_s > 0
        assert first.event.wait(5) and first.error is None
    assert srv.stats["shed"] == 1
    snap_keys = {"shed", "deadline_exceeded", "queue_depth"}
    assert snap_keys <= set(srv._snapshot_locked().keys())


def test_backoff_sequence_is_seeded_and_grows():
    a = _two_stage_server(backoff_seed=11)
    b = _two_stage_server(backoff_seed=11)
    seq_a, seq_b = [], []
    for srv, seq in ((a, seq_a), (b, seq_b)):
        for i in range(6):
            srv._consec_sheds = i
            seq.append(srv._retry_after_s())
    assert seq_a == seq_b                  # same seed, same hints
    # exponential growth dominates the 25% jitter band
    assert seq_a[3] > seq_a[0] and seq_a[5] > seq_a[2]
    assert max(seq_a) <= a.backoff_max_s * 1.25 + 1e-9
    c = _two_stage_server(backoff_seed=12)
    assert [c._retry_after_s() for _ in range(3)] != seq_a[:3]


def test_snapshot_empty_window_is_neutral():
    """Regression (ISSUE 8 satellite): a zero-completion delta window
    yields a neutral record — no crash, no NaN, no division blowup."""
    srv = _two_stage_server()
    srv.snapshot()                          # reset
    snap = srv.snapshot()                   # empty window
    assert snap["requests"] == 0 and snap["completed"] == 0
    assert snap["throughput_rps"] == 0.0
    assert snap["latency"]["n"] == 0 and snap["latency"]["p99_s"] == 0.0
    assert snap["stage_items"] == [0, 0]
    assert snap["stage_time_per_req_s"] == [0.0, 0.0]
    assert all(x == x for x in snap["stage_time_per_req_s"])   # no NaN
    srv.stop()


def test_snapshot_carries_per_item_stage_times():
    srv = _two_stage_server()
    with srv:
        srv.snapshot()
        outs = srv.serve_batch([1, 2, 3, 4])
        assert outs == [1, 2, 3, 4]
        snap = srv.snapshot()
    assert snap["stage_items"] == [4, 4]
    assert all(t >= 0.0 for t in snap["stage_time_per_req_s"])
    assert snap["stage_busy_s"][0] == pytest.approx(
        snap["stage_time_per_req_s"][0] * 4)


def test_items_snapshot_monotonic_and_reconfigure_rebases():
    g = synthetic_cnn(600).to_layer_graph()
    pl = plan(g, 2)
    srv = PipelinedModelServer(pl, [lambda x: x, lambda x: x])
    with srv:
        srv.serve_batch([1, 2, 3])
        assert srv.executor.items_snapshot() == [3, 3]
        srv.snapshot()
        srv.reconfigure(plan(g, 3), [lambda x: x] * 3)
        snap = srv.snapshot()               # rebased: no negative deltas
        assert snap["stage_items"] == [0, 0, 0]
        srv.serve_batch([5])
        assert srv.executor.items_snapshot() == [1, 1, 1]


# ---------------------------------------------------------------------------
# spec knobs
# ---------------------------------------------------------------------------
def test_spec_selfheal_knobs_validate_and_round_trip():
    s = DeploymentSpec(stages=2, deadline_ms=40.0, shed_policy="deadline",
                       drift_threshold=0.4, canary_requests=3)
    assert DeploymentSpec.from_json(s.to_json()) == s
    with pytest.raises(ValueError, match="deadline_ms"):
        DeploymentSpec(stages=2, deadline_ms=-1.0)
    with pytest.raises(ValueError, match="shed_policy"):
        DeploymentSpec(stages=2, shed_policy="lifo")
    with pytest.raises(ValueError, match="needs deadline_ms"):
        DeploymentSpec(stages=2, shed_policy="deadline")
    with pytest.raises(ValueError, match="drift_threshold"):
        DeploymentSpec(stages=2, drift_threshold=-0.1)
    with pytest.raises(ValueError, match="canary_requests"):
        DeploymentSpec(stages=2, canary_requests=0)


def test_drift_policy_validates():
    with pytest.raises(ValueError):
        DriftPolicy(hysteresis=0)
    with pytest.raises(ValueError):
        DriftPolicy(ewma_alpha=0.0)
    with pytest.raises(ValueError):
        DriftPolicy(backoff_base_windows=4, backoff_max_windows=2)
