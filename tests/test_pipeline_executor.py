"""Persistent PipelineExecutor lifecycle tests: zero thread growth in steady
state, error propagation without killing the workers, clean shutdown under
``with``, restart, and large-batch (bigger than queue capacity) safety."""
import threading
import time

import pytest

from repro.core.pipeline import (PipelineExecutor, ShapeKeyedStageCache,
                                 simulated_stage)
from repro.serving import PipelinedModelServer
from conftest import api_plan as plan
from repro.models.cnn import synthetic_cnn


def test_steady_state_creates_no_threads():
    ex = PipelineExecutor([lambda x: x + 1, lambda x: x * 2, lambda x: x - 1])
    ex.run_batch([0])             # warm: spawns stage workers + collector
    n0 = threading.active_count()
    for _ in range(20):
        outs, _ = ex.run_batch(list(range(15)))
        assert outs == [(i + 1) * 2 - 1 for i in range(15)]
        assert threading.active_count() == n0
    ex.stop()
    # stage workers + tail collector are gone
    assert ex.n_threads == ex.n_stages + 1
    assert threading.active_count() == n0 - ex.n_threads


def test_context_manager_clean_shutdown():
    baseline = threading.active_count()
    with PipelineExecutor([simulated_stage(0.001), simulated_stage(0.001)]) as ex:
        assert ex.started
        assert threading.active_count() == baseline + ex.n_threads
        outs, _ = ex.run_batch([1, 2, 3])
        assert outs == [1, 2, 3]
    assert not ex.started
    assert threading.active_count() == baseline


def test_error_propagates_and_executor_stays_usable():
    def boom(x):
        if x == "bad":
            raise ValueError("stage died")
        return x

    ex = PipelineExecutor([lambda x: x, boom, lambda x: x])
    with pytest.raises(ValueError, match="stage died"):
        ex.run_batch([1, "bad", 3])
    n0 = threading.active_count()
    # workers survived the failure; good items still flow, in order
    outs, _ = ex.run_batch([4, 5, 6])
    assert outs == [4, 5, 6]
    assert threading.active_count() == n0
    ex.stop()


def test_partial_failure_keeps_good_items_ordered():
    def boom(x):
        if x % 3 == 0:
            raise RuntimeError(f"item {x}")
        return x * 10

    ex = PipelineExecutor([boom])
    with pytest.raises(RuntimeError):
        ex.run_batch(list(range(7)))
    outs, _ = ex.run_batch([1, 2, 4])
    assert outs == [10, 20, 40]
    ex.stop()


def test_batch_larger_than_queue_capacity():
    ex = PipelineExecutor([lambda x: x + 1, lambda x: x * 2], queue_size=4)
    outs, _ = ex.run_batch(list(range(100)))
    assert outs == [(i + 1) * 2 for i in range(100)]
    ex.stop()


def test_restart_after_stop():
    ex = PipelineExecutor([lambda x: x * 3])
    assert ex.run_batch([1, 2])[0] == [3, 6]
    ex.stop()
    assert ex.run_batch([3])[0] == [9]      # auto-restarts
    ex.stop()


def test_busy_times_are_per_batch():
    ex = PipelineExecutor([simulated_stage(0.01), simulated_stage(0.002)])
    _, busy1 = ex.run_batch([0] * 5, collect_stage_times=True)
    _, busy2 = ex.run_batch([0] * 5, collect_stage_times=True)
    # counters reset between batches (not cumulative)
    assert busy1[0] == pytest.approx(0.05, rel=0.5)
    assert busy2[0] == pytest.approx(0.05, rel=0.5)
    assert busy1[0] > busy1[1]
    ex.stop()


def test_server_owns_persistent_executor_and_closes_it():
    g = synthetic_cnn(600).to_layer_graph()
    pl = plan(g, 2, "balanced_norefine")
    baseline = threading.active_count()
    with PipelinedModelServer(pl, [lambda x: x + 1, lambda x: x * 2]) as srv:
        srv.serve_batch([1])
        n0 = threading.active_count()
        for _ in range(5):
            assert srv.serve_batch([1, 2, 3]) == [4, 6, 8]
            assert threading.active_count() == n0
    assert threading.active_count() == baseline


def test_shape_keyed_stage_cache_builds_once_per_signature():
    cache = ShapeKeyedStageCache()
    builds = []

    def build():
        builds.append(1)
        return lambda x: x * 2

    stage = cache.wrap("s0", build)
    assert stage(3) == 6 and stage(4) == 8
    assert len(builds) == 1                 # same signature -> one build

    class Arr:                              # array-like with shape/dtype
        def __init__(self, shape):
            self.shape, self.dtype = shape, "f32"

        def __mul__(self, k):
            return ("arr", self.shape, k)

    assert stage(Arr((1, 8)))[1] == (1, 8)
    assert stage(Arr((1, 16)))[1] == (1, 16)
    assert stage(Arr((1, 8)))[1] == (1, 8)
    assert len(builds) == 3                 # one more per new shape only
    assert len(cache) == 3
