"""Per-arch smoke tests (assignment requirement): reduced same-family
configs, one forward + one train step on CPU, shape + finiteness asserts,
plus prefill/decode consistency for every family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.common import SHAPES, concrete_batch, input_specs
from repro.launch import steps as steps_lib
from repro.models import api
from repro.optim import AdamWConfig

ARCHS = configs.arch_ids()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = configs.get(arch).smoke_config()
    params = api.init(cfg, jax.random.PRNGKey(0))
    seq = 16 + (cfg.n_patches if cfg.family == "vlm" else 0)
    batch = concrete_batch(cfg, seq, 2)
    logits = api.forward(cfg, params, batch)
    assert logits.shape == (2, seq, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # last_token_only path agrees with the full pass
    last = api.forward(cfg, params, batch, last_token_only=True)
    np.testing.assert_allclose(np.asarray(last[:, 0]),
                               np.asarray(logits[:, -1]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_is_finite(arch):
    cfg = configs.get(arch).smoke_config()
    params, opt = steps_lib.init_train_state(cfg, jax.random.PRNGKey(0))
    seq = 16 + (cfg.n_patches if cfg.family == "vlm" else 0)
    batch = concrete_batch(cfg, seq, 2, kind="train")
    step = jax.jit(steps_lib.make_train_step(cfg, AdamWConfig(lr=1e-3),
                                             loss_chunk=seq))
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda a, b: float(jnp.sum(jnp.abs(
            a.astype(jnp.float32) - b.astype(jnp.float32)))), params, p2))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    """Teacher-forced forward logits at position t must equal step-by-step
    cached decode — the strongest cache-correctness check."""
    cfg = configs.get(arch).smoke_config()
    if cfg.family == "vlm":
        pytest.skip("vlm decode starts from a vision prefix; covered by "
                    "dense (same code path)")
    params = api.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 8
    batch = concrete_batch(cfg, s, b, kind="prefill")
    ref = api.forward(cfg, params, batch)            # (B, S, V)

    cache = api.init_cache(cfg, b, max_len=s)
    if cfg.family == "encdec":
        from repro.models import whisper
        memory = whisper.encode(cfg, params, batch["frames"])
        cache = whisper.init_cache(cfg, b, s, memory=memory, params=params)
    toks = batch["tokens"]
    outs = []
    for t in range(s):
        lg, cache = api.decode(cfg, params, toks[:, t:t + 1], cache)
        outs.append(lg[:, 0])
    got = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_cover_all_shapes(arch):
    mod = configs.get(arch)
    cfg = mod.config()
    for name, spec in SHAPES.items():
        if name in mod.SKIP_SHAPES:
            continue
        specs = input_specs(cfg, spec)
        assert "tokens" in specs
        if spec.kind == "train":
            assert "labels" in specs
        if cfg.family == "vlm" and spec.kind != "decode":
            assert "embeds" in specs and "positions" in specs
        if cfg.family == "encdec" and spec.kind != "decode":
            assert "frames" in specs


def test_long_500k_skips_documented():
    """Exactly the sub-quadratic archs run long_500k."""
    runners = [a for a in ARCHS
               if "long_500k" not in configs.get(a).SKIP_SHAPES]
    assert sorted(runners) == ["recurrentgemma-9b", "rwkv6-1.6b"]
    for a in ARCHS:
        for shape, reason in configs.get(a).SKIP_SHAPES.items():
            assert len(reason) > 10      # a real documented reason


def test_full_configs_match_assignment():
    """Exact assigned hyperparameters."""
    c = configs.get("qwen2.5-14b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (48, 5120, 40, 8, 13824, 152064)
    assert c.qkv_bias
    c = configs.get("qwen3-1.7b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (28, 2048, 16, 8, 6144, 151936)
    assert c.qk_norm
    c = configs.get("phi3-mini-3.8b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 3072, 32, 32, 8192, 32064)
    c = configs.get("minitron-4b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (32, 3072, 24, 8, 9216, 256000)
    c = configs.get("qwen2-vl-72b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (80, 8192, 64, 8, 29568, 152064)
    c = configs.get("granite-moe-1b-a400m").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.n_experts, c.top_k) == (24, 1024, 16, 8, 512,
                                               49155, 32, 8)
    c = configs.get("phi3.5-moe-42b-a6.6b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab, c.n_experts, c.top_k) == (32, 4096, 32, 8, 6400,
                                               32064, 16, 2)
    c = configs.get("whisper-tiny").config()
    assert (c.n_layers, c.n_enc_layers, c.d_model, c.n_heads, c.d_ff,
            c.vocab) == (4, 4, 384, 6, 1536, 51865)
    c = configs.get("recurrentgemma-9b").config()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (38, 4096, 16, 1, 12288, 256000)
    c = configs.get("rwkv6-1.6b").config()
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (24, 2048, 7168,
                                                        65536)


def test_param_counts_match_names():
    """Arch names encode their sizes; eval_shape counts must land close."""
    expect = {"qwen2.5-14b": 14.8e9, "qwen3-1.7b": 1.7e9,
              "phi3-mini-3.8b": 3.8e9, "minitron-4b": 4.2e9,
              "qwen2-vl-72b": 72.7e9, "granite-moe-1b-a400m": 1.3e9,
              "phi3.5-moe-42b-a6.6b": 41.9e9, "whisper-tiny": 39e6,
              "recurrentgemma-9b": 8.5e9, "rwkv6-1.6b": 1.6e9}
    for arch, n in expect.items():
        got = api.param_count(configs.get(arch).config())
        assert abs(got - n) / n < 0.12, (arch, got, n)
    # MoE active counts
    assert abs(api.active_param_count(
        configs.get("granite-moe-1b-a400m").config()) - 0.43e9) < 0.1e9
    assert abs(api.active_param_count(
        configs.get("phi3.5-moe-42b-a6.6b").config()) - 6.6e9) < 0.7e9
