"""Flash-decode kernel allclose sweeps vs the jnp oracle (interpret mode),
including partial-cache masking and consistency with decode_attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("b,hq,hkv,t,d,bk,cache_len", [
    (1, 2, 2, 256, 64, 128, 256),       # full cache
    (2, 4, 2, 256, 64, 128, 200),       # partial (mid-block mask)
    (1, 8, 1, 512, 128, 128, 130),      # MQA, just past one block
    (2, 4, 4, 128, 64, 64, 1),          # single valid entry
    (1, 2, 2, 256, 64, 256, 256),       # one big block
])
def test_flash_decode_vs_ref(b, hq, hkv, t, d, bk, cache_len):
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    out = flash_decode(q, k, v, jnp.asarray(cache_len, jnp.int32), bk=bk,
                       interpret=True)
    expect = ref.flash_decode_ref(q, k, v, cache_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-6, atol=2e-6)


def test_flash_decode_bf16():
    b, hq, hkv, t, d = 1, 4, 2, 256, 64
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.bfloat16)
    out = flash_decode(q, k, v, jnp.asarray(180, jnp.int32), interpret=True)
    expect = ref.flash_decode_ref(q, k, v, 180)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("group", [1, 2, 4, 8])
def test_flash_decode_gqa_group_sizes(group):
    """Every GQA fold from MHA (group=1) to MQA (group=Hq): q-head h must
    read kv-head h // group."""
    b, hq, t, d, bk = 2, 8, 256, 32, 64
    hkv = hq // group
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    out = flash_decode(q, k, v, jnp.asarray(193, jnp.int32), bk=bk,
                       interpret=True)
    expect = ref.flash_decode_ref(q, k, v, 193)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-6, atol=2e-6)


def _softmax_attention(q, ks, vs):
    """Oracle: one query row against a chronological (b, hkv, n, d) set,
    GQA-folded, computed in plain fp32 numpy."""
    b, hq, d = q.shape
    hkv = ks.shape[1]
    group = hq // hkv
    out = np.zeros((b, hq, d), np.float32)
    for bb in range(b):
        for h in range(hq):
            s = ks[bb, h // group] @ q[bb, h] / np.sqrt(d)
            p = np.exp(s - s.max())
            p /= p.sum()
            out[bb, h] = p @ vs[bb, h // group]
    return out


@pytest.mark.parametrize("cache_len", [63, 64, 65, 101, 128, 150])
def test_flash_decode_ring_buffer_wraparound(cache_len):
    """Windowed layers keep a ring cache of T == window slots: token i
    lives at slot i % T and the newest write lands at (cache_len-1) % T.
    Past wrap-around the kernel (fed valid_len = min(cache_len, T)) must
    equal attention over the *chronological* last-T tokens — softmax is
    permutation-invariant over the KV set, so the ring layout is free."""
    b, hq, hkv, t, d, bk = 2, 4, 2, 64, 32, 32
    stream = 150
    q = np.asarray(RNG.normal(size=(b, hq, d)), np.float32)
    ks = np.asarray(RNG.normal(size=(b, hkv, stream, d)), np.float32)
    vs = np.asarray(RNG.normal(size=(b, hkv, stream, d)), np.float32)

    ring_k = np.zeros((b, hkv, t, d), np.float32)
    ring_v = np.zeros((b, hkv, t, d), np.float32)
    for i in range(cache_len):               # the model's mod-T writes
        ring_k[:, :, i % t] = ks[:, :, i]
        ring_v[:, :, i % t] = vs[:, :, i]

    valid = min(cache_len, t)
    out = flash_decode(jnp.asarray(q), jnp.asarray(ring_k),
                       jnp.asarray(ring_v),
                       jnp.asarray(valid, jnp.int32), bk=bk,
                       interpret=True)
    lo = cache_len - valid
    expect = _softmax_attention(q, ks[:, :, lo:cache_len],
                                vs[:, :, lo:cache_len])
    np.testing.assert_allclose(np.asarray(out), expect,
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_windowed_layer_vs_decode_attention():
    """A windowed layer on a *linear* (non-ring) cache: gathering the
    window into a contiguous cache for the kernel must match
    decode_attention's window mask on the full cache."""
    from repro.models.attention import decode_attention
    b, hq, hkv, t, d, w = 2, 4, 2, 256, 32, 64
    cache_len = 150
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    model_out = decode_attention(
        q.reshape(b, 1, hq, d), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), jnp.asarray(cache_len), window=w)
    kern_out = flash_decode(q, k[:, :, cache_len - w:cache_len],
                            v[:, :, cache_len - w:cache_len],
                            jnp.asarray(w, jnp.int32), bk=32,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(kern_out),
                               np.asarray(model_out[:, 0]),
                               rtol=2e-5, atol=2e-5)


def test_flash_decode_matches_model_decode_attention():
    """The kernel must agree with the model's decode_attention path."""
    from repro.models.attention import decode_attention
    b, hq, hkv, t, d = 2, 4, 2, 128, 32
    q3 = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    cache_len = 77
    # model path expects (B, 1, H, D) queries and (B, T, H, D) caches
    model_out = decode_attention(
        q3[:, None].transpose(0, 1, 2, 3).reshape(b, 1, hq, d),
        k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        jnp.asarray(cache_len))
    kern_out = flash_decode(q3, k, v, jnp.asarray(cache_len, jnp.int32),
                            bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(kern_out),
                               np.asarray(model_out[:, 0]),
                               rtol=2e-5, atol=2e-5)
