"""Flash-decode kernel allclose sweeps vs the jnp oracle (interpret mode),
including partial-cache masking and consistency with decode_attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_decode import flash_decode

RNG = np.random.default_rng(7)


@pytest.mark.parametrize("b,hq,hkv,t,d,bk,cache_len", [
    (1, 2, 2, 256, 64, 128, 256),       # full cache
    (2, 4, 2, 256, 64, 128, 200),       # partial (mid-block mask)
    (1, 8, 1, 512, 128, 128, 130),      # MQA, just past one block
    (2, 4, 4, 128, 64, 64, 1),          # single valid entry
    (1, 2, 2, 256, 64, 256, 256),       # one big block
])
def test_flash_decode_vs_ref(b, hq, hkv, t, d, bk, cache_len):
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    out = flash_decode(q, k, v, jnp.asarray(cache_len, jnp.int32), bk=bk,
                       interpret=True)
    expect = ref.flash_decode_ref(q, k, v, cache_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-6, atol=2e-6)


def test_flash_decode_bf16():
    b, hq, hkv, t, d = 1, 4, 2, 256, 64
    q = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.bfloat16)
    out = flash_decode(q, k, v, jnp.asarray(180, jnp.int32), interpret=True)
    expect = ref.flash_decode_ref(q, k, v, 180)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_flash_decode_matches_model_decode_attention():
    """The kernel must agree with the model's decode_attention path."""
    from repro.models.attention import decode_attention
    b, hq, hkv, t, d = 2, 4, 2, 128, 32
    q3 = jnp.asarray(RNG.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), jnp.float32)
    cache_len = 77
    # model path expects (B, 1, H, D) queries and (B, T, H, D) caches
    model_out = decode_attention(
        q3[:, None].transpose(0, 1, 2, 3).reshape(b, 1, hq, d),
        k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
        jnp.asarray(cache_len))
    kern_out = flash_decode(q3, k, v, jnp.asarray(cache_len, jnp.int32),
                            bk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(kern_out),
                               np.asarray(model_out[:, 0]),
                               rtol=2e-5, atol=2e-5)
