"""HLO cost-model tests (trip-count scaling, collectives parsing) and the
chunked-loss equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import HloCostModel, analyze
from repro.launch.collectives import collective_bytes


def test_flops_single_matmul():
    n = 128
    f = jax.jit(lambda a, b: a @ b)
    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    compiled = f.lower(x, x).compile()
    tot = analyze(compiled.as_text())
    assert tot.flops == pytest.approx(2 * n ** 3, rel=0.01)


def test_flops_scan_scales_by_trip_count():
    """cost_analysis counts a while body once; the analyzer must multiply
    by the trip count."""
    n, trips = 64, 12

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=trips)
        return c

    x = jax.ShapeDtypeStruct((n, n), jnp.float32)
    compiled = jax.jit(f).lower(x, x).compile()
    tot = analyze(compiled.as_text())
    assert tot.flops == pytest.approx(trips * 2 * n ** 3, rel=0.05)


def test_collectives_parser_on_crafted_hlo():
    hlo = """
HLO module m

ENTRY %main (p0: f32[8,128]) -> f32[8,128] {
  %p0 = f32[8,128]{1,0} parameter(0)
  %ar = f32[8,128]{1,0} all-reduce(f32[8,128]{1,0} %p0), replica_groups={}
  %ag = f32[16,128]{1,0} all-gather(f32[8,128]{1,0} %ar), dimensions={0}
  ROOT %out = f32[8,128]{1,0} reduce-scatter(f32[16,128]{1,0} %ag), dimensions={0}
}
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 8 * 128 * 4
    assert out["all-gather"] == 8 * 128 * 4
    assert out["reduce-scatter"] == 16 * 128 * 4
    assert out["total"] == (8 + 8 + 16) * 128 * 4


def test_hlo_model_nested_while():
    hlo_model_entry_check = """
HLO module m

%inner_cond (p: (s32[], f32[4,4])) -> pred[] {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(5)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%inner_body (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {
  %p = (s32[], f32[4,4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4,4]{1,0} get-tuple-element(%p), index=1
  %d = f32[4,4]{1,0} dot(f32[4,4]{1,0} %x, f32[4,4]{1,0} %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4,4]) tuple(%ip, %d)
}

ENTRY %main (p0: f32[4,4]) -> f32[4,4] {
  %p0 = f32[4,4]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[4,4]) tuple(%zero, %p0)
  %w = (s32[], f32[4,4]) while(%init), condition=%inner_cond, body=%inner_body
  ROOT %out = f32[4,4]{1,0} get-tuple-element(%w), index=1
}
"""
    tot = analyze(hlo_model_entry_check)
    assert tot.flops == pytest.approx(5 * 2 * 4 ** 3)


def test_chunked_loss_equals_direct():
    from repro import configs
    from repro.configs.common import concrete_batch
    from repro.launch.steps import chunked_lm_loss
    from repro.models import api
    from repro.models.lm import lm_loss

    cfg = configs.get("qwen3-1.7b").smoke_config()
    params = api.init(cfg, jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, 32, 2, kind="train")
    direct = lm_loss(api.forward(cfg, params, batch), batch["labels"])
    hidden = api.forward_hidden(cfg, params, batch)
    for chunk in (8, 16, 32):
        chunked = chunked_lm_loss(cfg, params, hidden, batch["labels"],
                                  chunk=chunk)
        assert float(chunked) == pytest.approx(float(direct), rel=1e-5)


def test_chunked_loss_grads_match():
    from repro import configs
    from repro.configs.common import concrete_batch
    from repro.launch.steps import chunked_lm_loss
    from repro.models import api
    from repro.models.lm import lm_loss

    cfg = configs.get("qwen3-1.7b").smoke_config()
    params = api.init(cfg, jax.random.PRNGKey(1))
    batch = concrete_batch(cfg, 16, 2, kind="train")

    def loss_direct(p):
        return lm_loss(api.forward(cfg, p, batch), batch["labels"])

    def loss_chunked(p):
        h = api.forward_hidden(cfg, p, batch)
        return chunked_lm_loss(cfg, p, h, batch["labels"], chunk=8)

    g1 = jax.grad(loss_direct)(params)
    g2 = jax.grad(loss_chunked)(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-5)
