"""Pallas kernel allclose sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.matmul_qi8 import matmul_qi8
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.ops import quantize_int8, quantized_dense

RNG = np.random.default_rng(42)


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n,block", [
    (128, 128, 128, (128, 128, 128)),
    (256, 384, 128, (128, 128, 128)),
    (384, 256, 512, (128, 128, 128)),
    (256, 256, 256, (128, 128, 64)),
    (512, 128, 256, (256, 128, 128)),
])
def test_matmul_qi8_exact(m, k, n, block):
    x = jnp.asarray(RNG.integers(-128, 128, (m, k), dtype=np.int8))
    w = jnp.asarray(RNG.integers(-128, 128, (k, n), dtype=np.int8))
    out = matmul_qi8(x, w, block=block, interpret=True)
    assert out.dtype == jnp.int32
    assert jnp.array_equal(out, ref.matmul_qi8_ref(x, w))


def test_matmul_qi8_block_mismatch_raises():
    x = jnp.zeros((100, 128), jnp.int8)
    w = jnp.zeros((128, 128), jnp.int8)
    with pytest.raises(AssertionError):
        matmul_qi8(x, w, interpret=True)


def test_quantize_roundtrip():
    x = jnp.asarray(RNG.normal(size=(64, 64)), jnp.float32)
    q, s = quantize_int8(x)
    np.testing.assert_allclose(np.asarray(q * s), np.asarray(x),
                               atol=float(s) * 0.51)
    y = quantized_dense(x, x.T)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x @ x.T),
                               rtol=0.05, atol=0.5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,hq,hkv,s,t,d,causal,dtype", [
    (1, 2, 2, 128, 128, 64, True, jnp.float32),
    (2, 4, 2, 256, 256, 64, True, jnp.float32),
    (1, 8, 1, 128, 256, 128, True, jnp.float32),     # MQA, s != t
    (2, 2, 2, 128, 128, 64, False, jnp.float32),
    (1, 4, 4, 256, 256, 64, True, jnp.bfloat16),
])
def test_flash_attention_vs_ref(b, hq, hkv, s, t, d, causal, dtype):
    q = jnp.asarray(RNG.normal(size=(b, hq, s, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, hkv, t, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_sizes():
    q = jnp.asarray(RNG.normal(size=(1, 2, 256, 64)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 2, 256, 64)), jnp.float32)
    base = ref.flash_attention_ref(q, k, v)
    for bq, bk in ((64, 64), (128, 64), (64, 128), (256, 256)):
        out = flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=2e-6, atol=2e-6)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,r,chunk", [
    (1, 128, 128, 64), (2, 256, 256, 128), (2, 512, 128, 512),
    (1, 256, 128, 256),
])
def test_rglru_scan_vs_ref(b, s, r, chunk):
    a = jnp.asarray(RNG.uniform(0.3, 1.0, (b, s, r)), jnp.float32)
    g = jnp.asarray(RNG.normal(size=(b, s, r)) * 0.2, jnp.float32)
    h0 = jnp.asarray(RNG.normal(size=(b, r)), jnp.float32)
    y, h = rglru_scan(a, g, h0, chunk=chunk, interpret=True)
    yr, hr = ref.rglru_scan_ref(a, g, h0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-5, atol=1e-5)


@given(st.integers(1, 3), st.integers(1, 4), st.data())
@settings(max_examples=20, deadline=None)
def test_rglru_recurrence_property(b, nchunks, data):
    """Chunked kernel == plain python recurrence for arbitrary sizes."""
    s, r = nchunks * 32, 8
    rng = np.random.default_rng(data.draw(st.integers(0, 2 ** 16)))
    a = rng.uniform(0.0, 1.0, (b, s, r)).astype(np.float32)
    g = rng.normal(size=(b, s, r)).astype(np.float32) * 0.5
    h0 = rng.normal(size=(b, r)).astype(np.float32)
    y, h = rglru_scan(jnp.asarray(a), jnp.asarray(g), jnp.asarray(h0),
                      chunk=32, interpret=True)
    href = h0.copy()
    ys = np.empty_like(a)
    for t in range(s):
        href = a[:, t] * href + g[:, t]
        ys[:, t] = href
    np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), href, rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# RWKV6 scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,h,s,d,chunk", [
    (1, 1, 128, 64, 64), (2, 2, 128, 64, 128), (1, 2, 256, 32, 64),
])
def test_rwkv6_scan_vs_ref(b, h, s, d, chunk):
    r = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, h, s, d)) * 0.2, jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.7, 1.0, (b, h, s, d)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(h, d)) * 0.2, jnp.float32)
    s0 = jnp.asarray(RNG.normal(size=(b, h, d, d)) * 0.1, jnp.float32)
    y, sl = rwkv6_scan(r, k, v, w, u, s0, chunk=chunk, interpret=True)
    yr, slr = ref.rwkv6_scan_ref(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(sl), np.asarray(slr),
                               rtol=2e-4, atol=2e-4)


def test_rwkv6_state_carries_across_chunks():
    """Same input split into chunks must equal one-shot (state handoff)."""
    b, h, s, d = 1, 1, 64, 16
    r = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, h, s, d)) * 0.2, jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, h, s, d)), jnp.float32)
    w = jnp.asarray(RNG.uniform(0.7, 1.0, (b, h, s, d)), jnp.float32)
    u = jnp.asarray(RNG.normal(size=(h, d)) * 0.2, jnp.float32)
    s0 = jnp.zeros((b, h, d, d), jnp.float32)
    y1, st1 = rwkv6_scan(r, k, v, w, u, s0, chunk=64, interpret=True)
    ya, sta = rwkv6_scan(r[:, :, :32], k[:, :, :32], v[:, :, :32],
                         w[:, :, :32], u, s0, chunk=32, interpret=True)
    yb, stb = rwkv6_scan(r[:, :, 32:], k[:, :, 32:], v[:, :, 32:],
                         w[:, :, 32:], u, sta, chunk=32, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([ya, yb], 2)),
                               np.asarray(y1), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(stb), np.asarray(st1),
                               rtol=1e-5, atol=1e-5)
