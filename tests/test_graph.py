"""LayerGraph DAG tests: depths, levels, cut-crossing bytes."""
import pytest

from repro.core.graph import LayerGraph, chain_graph


def diamond():
    g = LayerGraph("diamond")
    g.add_layer("in", params=1, macs=1, out_bytes=10)
    g.add_layer("a", params=2, macs=2, out_bytes=10, inputs=["in"])
    g.add_layer("b1", params=3, macs=3, out_bytes=10, inputs=["a"])
    g.add_layer("b2", params=4, macs=4, out_bytes=20, inputs=["a"])
    g.add_layer("c", params=5, macs=5, out_bytes=10, inputs=["b1", "b2"])
    return g


def test_depths_longest_path():
    g = LayerGraph("g")
    g.add_layer("in", out_bytes=1)
    g.add_layer("long1", inputs=["in"])
    g.add_layer("long2", inputs=["long1"])
    g.add_layer("short", inputs=["in"])
    # join: depth = 1 + max(depth(long2)=2, depth(short)=1) = 3
    g.add_layer("join", inputs=["long2", "short"])
    assert g.depths()["join"] == 3
    assert g.depth == 4


def test_levels_and_params_per_depth():
    g = diamond()
    assert g.params_per_depth() == [1, 2, 7, 5]
    assert [sorted(l) for l in g.levels()] == [["in"], ["a"], ["b1", "b2"],
                                               ["c"]]


def test_out_bytes_crossing_cuts():
    g = diamond()
    # cut after depth 0: only "in"->a crosses (10)
    # cut after depth 1: a feeds b1,b2 (10); cut after 2: b1+b2 (30)
    assert g.out_bytes_per_depth() == [10, 10, 30, 0]


def test_skip_connection_crosses_multiple_cuts():
    g = LayerGraph("skip")
    g.add_layer("in", out_bytes=5)
    g.add_layer("m1", inputs=["in"], out_bytes=7)
    g.add_layer("m2", inputs=["m1"], out_bytes=7)
    g.add_layer("end", inputs=["m2", "in"])   # skip from depth 0 to 3
    # cut after d0: only "in" crosses (5, counted once though used twice);
    # cuts after d1/d2: m1 or m2 (7) + the live skip tensor "in" (5)
    assert g.out_bytes_per_depth() == [5, 12, 12, 0]


def test_cycle_detection():
    g = LayerGraph("c")
    g.add_layer("a")
    g.add_layer("b", inputs=["a"])
    g._edges["b"].append("a")
    g._redges["a"].append("b")
    with pytest.raises(ValueError, match="cycle"):
        g.topological_order()


def test_duplicate_and_unknown():
    g = LayerGraph("d")
    g.add_layer("a")
    with pytest.raises(ValueError):
        g.add_layer("a")
    with pytest.raises(ValueError):
        g.add_layer("b", inputs=["zzz"])


def test_chain_graph_and_ranges():
    g = chain_graph("ch", [(f"l{i}", i, i, 1) for i in range(5)])
    assert g.depth == 5
    assert g.layers_in_depth_range(1, 3) == ["l1", "l2", "l3"]
    assert g.total_params == 10
