"""Runtime (fault tolerance, stragglers, elastic) and serving tests."""
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from conftest import api_plan as plan
from repro.core import EdgeTPUModel
from repro.core.pipeline import (PipelineExecutor, simulated_stage,
                                 stage_balance_metrics)
from repro.models.cnn import synthetic_cnn
from repro.runtime import (ElasticPlanner, FailureInjector, SpeculativeExecutor,
                           TrainSupervisor)
from repro.serving import MicroBatcher, PipelinedModelServer


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------
def _counting_step():
    seen = []

    def step_fn(state, step):
        seen.append(step)
        return {"x": state["x"] + 1}, {"loss": float(state["x"])}

    return step_fn, seen


def test_supervisor_restarts_from_checkpoint(tmp_path):
    step_fn, seen = _counting_step()
    store = CheckpointStore(str(tmp_path), keep=3)
    sup = TrainSupervisor(store, step_fn, ckpt_every=5, async_ckpt=False,
                          injector=FailureInjector(fail_at_steps=[12]))
    state, report = sup.run({"x": jnp.array(0)}, 20)
    assert report.restarts == 1
    assert report.final_step == 20
    # replayed steps 10..12 after restoring the step-10 checkpoint
    assert seen.count(11) == 2
    # state reflects exactly 20 effective steps (replay is idempotent
    # because state was restored)
    assert int(state["x"]) == 20


def test_supervisor_restart_budget(tmp_path):
    step_fn, _ = _counting_step()
    store = CheckpointStore(str(tmp_path))
    inj = FailureInjector(fail_at_steps=[])

    def always_fail(state, step):
        raise RuntimeError("boom")

    sup = TrainSupervisor(store, always_fail, ckpt_every=5, max_restarts=2,
                          async_ckpt=False)
    with pytest.raises(RuntimeError, match="restart budget"):
        sup.run({"x": jnp.array(0)}, 10)


def test_supervisor_resumes_across_runs(tmp_path):
    step_fn, _ = _counting_step()
    store = CheckpointStore(str(tmp_path), keep=3)
    sup = TrainSupervisor(store, step_fn, ckpt_every=5, async_ckpt=False)
    state, _ = sup.run({"x": jnp.array(0)}, 10)
    # a "new process" picks up from the latest checkpoint
    step_fn2, seen2 = _counting_step()
    sup2 = TrainSupervisor(store, step_fn2, ckpt_every=5, async_ckpt=False)
    state2, report2 = sup2.run({"x": jnp.array(0)}, 20)
    assert min(seen2) == 10               # did not replay from scratch
    assert int(state2["x"]) == 20


# ---------------------------------------------------------------------------
# stragglers
# ---------------------------------------------------------------------------
def test_speculative_executor_hedges_stragglers():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.2)               # first call straggles
        return x * 2

    ex = SpeculativeExecutor(flaky, hedge_after=0.03)
    assert ex.submit(21) == 42
    assert ex.hedged == 1
    ex.shutdown()


def test_speculative_executor_fast_path():
    ex = SpeculativeExecutor(lambda x: x + 1, hedge_after=0.5)
    assert ex.map([1, 2, 3]) == [2, 3, 4]
    assert ex.hedged == 0
    ex.shutdown()


# ---------------------------------------------------------------------------
# elastic replanning
# ---------------------------------------------------------------------------
def test_elastic_replan_is_fast_and_cached():
    g = synthetic_cnn(600).to_layer_graph()
    ep = ElasticPlanner(g, "balanced")
    p4 = ep.on_resize(4)
    p3 = ep.on_resize(3)                  # a device died
    assert p4.n_stages == 4 and p3.n_stages == 3
    assert ep.replan_times[3] < 1.0       # paper §2.2: fast partitioning
    assert ep.on_resize(4) is p4          # cached


# ---------------------------------------------------------------------------
# pipeline executor + analytical time model
# ---------------------------------------------------------------------------
def test_pipeline_order_and_errors():
    ex = PipelineExecutor([lambda x: x + 1, lambda x: x * 2])
    outs, busy = ex.run_batch(list(range(10)), collect_stage_times=True)
    assert outs == [(i + 1) * 2 for i in range(10)]
    assert len(busy) == 2

    def boom(x):
        raise ValueError("stage died")

    ex2 = PipelineExecutor([lambda x: x, boom])
    with pytest.raises(ValueError, match="stage died"):
        ex2.run_batch([1, 2])


def test_pipeline_time_matches_model():
    """Wall-clock of simulated stages ~= fill + (B-1)*max_stage."""
    lat = [0.01, 0.03, 0.01]
    ex = PipelineExecutor([simulated_stage(l) for l in lat])
    n = 10
    _, dt, busy = ex.timed_run(list(range(n)))
    model = sum(lat) + (n - 1) * max(lat)
    assert dt == pytest.approx(model, rel=0.35)
    m = stage_balance_metrics(busy)
    assert m["max_stage_s"] >= m["mean_stage_s"]


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------
def test_microbatcher_gathers_up_to_max():
    mb = MicroBatcher(max_batch=4, max_wait_s=0.05)
    for i in range(6):
        mb.submit(i)
    b1 = mb.next_batch()
    b2 = mb.next_batch()
    assert len(b1) == 4 and len(b2) == 2


def test_pipelined_server_end_to_end():
    g = synthetic_cnn(600).to_layer_graph()
    pl = plan(g, 3, "balanced_norefine")
    fns = [lambda x: x + 1, lambda x: x * 2, lambda x: x - 3]
    srv = PipelinedModelServer(pl, fns, max_batch=8, max_wait_s=0.02)
    outs = srv.serve_batch([1, 2, 3])
    assert outs == [(x + 1) * 2 - 3 for x in (1, 2, 3)]
    srv.start()
    reqs = [srv.submit(i) for i in range(5)]
    for i, r in enumerate(reqs):
        assert r.event.wait(5)
        assert r.result == (i + 1) * 2 - 3
    srv.stop()
    assert srv.stats["requests"] >= 8
