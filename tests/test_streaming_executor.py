"""Streaming executor tests: submit()/Future semantics, equivalence with
run_batch (ordering, failure forwarding, replicated stages) under a
randomized concurrent-submitter stress, stop() completing in-flight
futures, monotonic busy accounting, and shape-bucketed dynamic
micro-batching."""
import random
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import (PipelineExecutor, PipelineStopped,
                                 simulated_stage, stage_balance_metrics)
from repro.runtime import ElasticPlanner
from repro.serving import (MicroBatcher, PipelinedModelServer, Request,
                           latency_percentiles)
from conftest import api_plan as plan
from repro.models.cnn import synthetic_cnn


# ---------------------------------------------------------------------------
# submit() semantics
# ---------------------------------------------------------------------------
def test_submit_returns_future_with_result():
    with PipelineExecutor([lambda x: x + 1, lambda x: x * 2]) as ex:
        futs = [ex.submit(i) for i in range(10)]
        assert [f.result(timeout=5) for f in futs] == \
            [(i + 1) * 2 for i in range(10)]
        assert ex.in_flight == 0


def test_submit_failure_resolves_future_with_original_error():
    def boom(x):
        if x == 3:
            raise ValueError("item three")
        return x * 10

    with PipelineExecutor([boom]) as ex:
        futs = [ex.submit(i) for i in range(6)]
        for i, f in enumerate(futs):
            if i == 3:
                with pytest.raises(ValueError, match="item three"):
                    f.result(timeout=5)
            else:
                assert f.result(timeout=5) == i * 10


def test_submit_after_stop_raises():
    ex = PipelineExecutor([lambda x: x])
    ex.run_batch([1])
    ex.stop()
    # a stopped executor restarts on submit (same contract as run_batch)
    assert ex.submit(2).result(timeout=5) == 2
    ex.stop()


def test_streams_interleave_without_barrier():
    """Two callers' items overlap in flight; each gets its own results."""
    with PipelineExecutor([simulated_stage(0.002), lambda x: x * 2]) as ex:
        a = [ex.submit(("a", i)) for i in range(8)]
        b = [ex.submit(("b", i)) for i in range(8)]
        assert [f.result(timeout=5) for f in a] == \
            [("a", i, "a", i) for i in range(8)]
        assert [f.result(timeout=5) for f in b] == \
            [("b", i, "b", i) for i in range(8)]


# ---------------------------------------------------------------------------
# streaming vs run_batch equivalence (ordering, failures, replicas)
# ---------------------------------------------------------------------------
def _jittered_fns(seed):
    rng = random.Random(seed)

    def jitter(x):
        time.sleep(rng.random() * 0.002)
        return x * 2.0 + 1.0

    return [lambda x: x + 0.5, jitter, lambda x: x - 0.25]


@pytest.mark.parametrize("replicas", [None, [1, 4, 1]])
def test_streaming_matches_run_batch_bit_identical(replicas):
    fns = _jittered_fns(0)
    inputs = [i * 0.1 for i in range(40)]
    with PipelineExecutor(fns) as base:
        expect, _ = base.run_batch(inputs)
    with PipelineExecutor(fns, replicas=replicas) as ex:
        futs = [ex.submit(x) for x in inputs]
        streamed = [f.result(timeout=10) for f in futs]
        assert streamed == expect          # same floats, same order
        batched, _ = ex.run_batch(inputs)  # run_batch over the same stream
        assert batched == expect


@pytest.mark.parametrize("replicas", [None, [2, 3]])
def test_concurrent_submitters_randomized_stress(replicas):
    """Several threads submit interleaved items (some failing) through a
    jittery, optionally replicated pipeline; every thread sees its own
    results, in its own order, with failures attributed per item."""
    rng = random.Random(42)

    def jitter(x):
        time.sleep(rng.random() * 0.001)
        return x

    def boom(x):
        if x[1] % 7 == 3:
            raise ValueError(f"bad {x}")
        return (x[0], x[1] * 2)

    n_threads, n_items = 4, 30
    results = [None] * n_threads

    with PipelineExecutor([jitter, boom], queue_size=8,
                          replicas=replicas) as ex:
        def submitter(t):
            futs = [ex.submit((t, i)) for i in range(n_items)]
            out = []
            for i, f in enumerate(futs):
                try:
                    out.append(f.result(timeout=30))
                except ValueError:
                    out.append("failed")
            results[t] = out

        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=60)
            assert not th.is_alive(), "submitter hung"

    for t in range(n_threads):
        expect = ["failed" if i % 7 == 3 else (t, i * 2)
                  for i in range(n_items)]
        assert results[t] == expect


def test_run_batch_first_error_in_submission_order_after_drain():
    def boom(x):
        if x % 3 == 0:
            raise RuntimeError(f"item {x}")
        return x

    ex = PipelineExecutor([boom])
    with pytest.raises(RuntimeError, match="item 0"):
        ex.run_batch(list(range(7)))
    outs, _ = ex.run_batch([1, 2, 4])      # drained, still usable
    assert outs == [1, 2, 4]
    ex.stop()


# ---------------------------------------------------------------------------
# stop() with in-flight futures
# ---------------------------------------------------------------------------
def test_stop_completes_inflight_futures_not_hang():
    ex = PipelineExecutor([simulated_stage(0.25)])
    futs = [ex.submit(i) for i in range(6)]
    time.sleep(0.05)
    t0 = time.perf_counter()
    ex.stop(timeout=0.2)                   # too short to drain 1.5s of work
    assert time.perf_counter() - t0 < 2.0
    for f in futs:
        try:
            f.result(timeout=0.5)          # completed normally before stop
        except PipelineStopped:
            pass                           # or cancelled by stop — never hangs


def test_clean_stop_drains_inflight_normally():
    ex = PipelineExecutor([simulated_stage(0.02)])
    futs = [ex.submit(i) for i in range(5)]
    ex.stop()                              # default timeout: full drain
    assert [f.result(timeout=0.1) for f in futs] == list(range(5))


# ---------------------------------------------------------------------------
# monotonic busy accounting
# ---------------------------------------------------------------------------
def test_busy_counters_are_monotonic_with_snapshot_deltas():
    ex = PipelineExecutor([simulated_stage(0.01), simulated_stage(0.002)])
    _, busy1 = ex.run_batch([0] * 5, collect_stage_times=True)
    _, busy2 = ex.run_batch([0] * 5, collect_stage_times=True)
    # per-batch deltas, not cumulative (loose bounds: sleeps overshoot
    # under load; the monotonicity property below is the real assertion)
    assert 0.02 < busy1[0] < 0.3
    assert 0.02 < busy2[0] < 0.3
    # ...while the raw snapshot keeps growing
    total = ex.busy_snapshot()
    assert total[0] == pytest.approx(busy1[0] + busy2[0], rel=0.01)
    ex.stop()


def test_stage_balance_metrics_empty_is_neutral():
    m = stage_balance_metrics([])
    assert m == {"max_stage_s": 0.0, "mean_stage_s": 0.0,
                 "max_minus_mean_s": 0.0, "balance": 1.0}
    # and a snapshot interval with traffic still works end to end
    m2 = stage_balance_metrics([0.5, 0.25, 0.25])
    assert m2["balance"] == pytest.approx(1 / 1.5)


# ---------------------------------------------------------------------------
# dynamic micro-batching
# ---------------------------------------------------------------------------
def test_microbatch_stacks_same_shape_prefix_and_preserves_order():
    sizes = []

    def fn(x):
        sizes.append(int(x.shape[0]))
        return x * 2.0

    with PipelineExecutor([fn], microbatch=4,
                          microbatch_wait_s=0.02) as ex:
        payloads = [np.full((1, 3), float(i)) for i in range(12)]
        outs, _ = ex.run_batch(payloads)
    for i, o in enumerate(outs):
        assert o.shape == (1, 3) and float(o[0, 0]) == 2.0 * i
    assert any(s > 1 for s in sizes)       # something actually stacked
    snap = ex.microbatch_snapshot()
    assert snap["items"][0] >= snap["calls"][0]


def test_microbatch_mixed_shapes_bucket_breaks_keep_fifo():
    def fn(x):
        return x + 1.0

    with PipelineExecutor([fn], microbatch=8,
                          microbatch_wait_s=0.01) as ex:
        ps = [np.full((1, 2), float(i)) if i % 3 else
              np.full((1, 5), float(i)) for i in range(10)]
        outs, _ = ex.run_batch(ps)
    for p, o in zip(ps, outs):
        assert o.shape == p.shape and np.allclose(o, p + 1.0)


def test_microbatch_non_array_payloads_run_singly():
    with PipelineExecutor([lambda x: x * 2], microbatch=4) as ex:
        outs, _ = ex.run_batch([1, 2, 3])
    assert outs == [2, 4, 6]
    assert ex.microbatch_snapshot()["calls"] == [0]


def test_microbatch_unstackable_output_falls_back_per_item():
    probes = []

    def reduces(x):                        # (rows,3)->(1,3): wrong leading
        probes.append(int(x.shape[0]))
        return x.sum(axis=0, keepdims=True)

    with PipelineExecutor([reduces], microbatch=4,
                          microbatch_wait_s=0.02) as ex:
        ps = [np.full((2, 3), float(i)) for i in range(6)]
        outs, _ = ex.run_batch(ps)
        outs2, _ = ex.run_batch(ps)
    for o_list in (outs, outs2):
        for i, o in enumerate(o_list):
            assert o.shape == (1, 3) and float(o[0, 0]) == 2.0 * i
    # the stage is marked unstackable after at most one wasted probe:
    # no stacked call is ever counted, and later traffic runs per-item
    # without further stacked probes
    assert ex.microbatch_snapshot()["calls"] == [0]
    assert sum(1 for r in probes if r > 2) <= 1


def test_microbatch_failure_attributed_to_the_right_item():
    def maybe_boom(x):
        if np.any(x == 3.0):               # fails batched and singly
            raise ValueError("bad three")
        return x

    with PipelineExecutor([maybe_boom], microbatch=4,
                          microbatch_wait_s=0.02) as ex:
        futs = [ex.submit(np.full((1, 2), float(i))) for i in range(6)]
        for i, f in enumerate(futs):
            if i == 3:
                with pytest.raises(ValueError, match="bad three"):
                    f.result(timeout=5)
            else:
                assert float(f.result(timeout=5)[0, 0]) == float(i)


def test_microbatch_validation():
    with pytest.raises(ValueError):
        PipelineExecutor([lambda x: x], microbatch=[1, 2])
    with pytest.raises(ValueError):
        PipelineExecutor([lambda x: x], microbatch=0)


# ---------------------------------------------------------------------------
# streaming server
# ---------------------------------------------------------------------------
def _toy_server(n_stages=3, **kw):
    g = synthetic_cnn(600).to_layer_graph()
    pl = plan(g, n_stages, "balanced_norefine")
    fns = [lambda x: x + 1, lambda x: x * 2, lambda x: x - 3][:n_stages]
    return PipelinedModelServer(pl, fns, **kw), pl


def test_server_streaming_per_request_futures_and_snapshot():
    srv, _ = _toy_server(max_batch=4, max_wait_s=0.005)
    srv.start()
    reqs = [srv.submit(i) for i in range(9)]
    for i, r in enumerate(reqs):
        assert r.event.wait(5)
        assert r.error is None and r.result == (i + 1) * 2 - 3
        assert r.latency >= 0.0
    snap = srv.snapshot()
    assert snap["requests"] == 9 and snap["failed"] == 0
    assert snap["latency"]["n"] == 9
    assert snap["latency"]["p50_s"] <= snap["latency"]["p99_s"]
    assert len(snap["stage_busy_s"]) == 3
    # the window resets: an immediate snapshot sees nothing new
    assert srv.snapshot()["requests"] == 0
    srv.stop()


def test_server_stop_completes_unserved_requests_with_error():
    srv, _ = _toy_server(max_batch=2, max_wait_s=0.01)
    # never started: requests sit in the batcher until stop()
    reqs = [srv.submit(i) for i in range(3)]
    srv.stop()
    for r in reqs:
        assert r.event.wait(2), "request hung through stop()"
        assert r.error is not None
    assert srv.stats["failed"] == 3


def test_server_reconfigure_hot_swaps_plan_and_fns():
    srv, _ = _toy_server(max_batch=4, max_wait_s=0.005)
    srv.start()
    r = srv.submit(1)
    assert r.event.wait(5) and r.result == 1
    g = synthetic_cnn(600).to_layer_graph()
    pl2 = plan(g, 2, "balanced_norefine")
    srv.reconfigure(pl2, [lambda x: x + 10, lambda x: x * 3])
    assert srv.plan is pl2 and srv.executor.n_stages == 2
    r2 = srv.submit(1)
    assert r2.event.wait(5) and r2.result == 33
    srv.stop()


def test_elastic_planner_resize_server_hook():
    g = synthetic_cnn(600).to_layer_graph()
    pl = plan(g, 3, "balanced_norefine")
    srv = PipelinedModelServer(pl, [lambda x: x] * 3, max_batch=4,
                               max_wait_s=0.005)
    srv.start()
    ep = ElasticPlanner(g, "balanced_norefine")

    def builder(p):
        return [lambda x: x + 1] * p.n_stages

    pl2 = ep.resize_server(srv, builder, 2)   # a device left
    assert pl2.n_stages == 2 and srv.plan is pl2
    r = srv.submit(5)
    assert r.event.wait(5) and r.result == 7   # two +1 stages
    srv.stop()


# ---------------------------------------------------------------------------
# MicroBatcher + Request satellites
# ---------------------------------------------------------------------------
def test_microbatcher_deadline_starts_at_entry():
    """Waiting for the *first* request counts against max_wait_s: worst
    case is one window, not two (the old double-wait)."""
    mb = MicroBatcher(max_batch=8, max_wait_s=0.2)

    def late_put():
        time.sleep(0.12)
        mb.submit(1)

    threading.Thread(target=late_put, daemon=True).start()
    t0 = time.perf_counter()
    batch = mb.next_batch()
    dt = time.perf_counter() - t0
    assert len(batch) == 1
    assert dt < 0.32                       # old behavior: ~0.12 + 0.2

def test_microbatcher_empty_wait_is_bounded():
    mb = MicroBatcher(max_batch=4, max_wait_s=0.05)
    t0 = time.perf_counter()
    assert mb.next_batch() == []
    assert time.perf_counter() - t0 < 0.2


def test_request_ids_unique_across_reused_payloads():
    mb = MicroBatcher()
    payload = object()                     # same object every time
    rids = {mb.submit(payload).rid for _ in range(50)}
    assert len(rids) == 50
    # ids also survive payload GC / address reuse
    rids |= {mb.submit(tuple([i])).rid for i in range(50)}
    assert len(rids) == 100


def test_latency_percentiles_shapes():
    assert latency_percentiles([])["n"] == 0
    p = latency_percentiles([0.001 * i for i in range(1, 101)])
    assert p["p50_s"] <= p["p95_s"] <= p["p99_s"] <= p["max_s"]
    assert p["n"] == 100
