"""Distribution tests that need multiple devices: run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count set locally (the main test
process must keep the real 1-device topology)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_spmd_pipeline_matches_direct():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.configs.common import concrete_batch
        from repro.models import api, lm_graph
        from repro.api import DeploymentSpec
        from repro.api import plan as api_plan
        from repro.launch.pipeline_spmd import pipeline_logits
        from repro.launch.mesh import make_mesh

        cfg = configs.get("qwen3-1.7b").smoke_config()
        mesh = make_mesh((1, 4), ("data", "model"))
        params = api.init(cfg, jax.random.PRNGKey(0))
        batch = concrete_batch(cfg, 16, 8, kind="prefill")
        g = lm_graph.lm_layer_graph(cfg, seq_len=16)
        pl = api_plan(DeploymentSpec(stages=4,
                                     strategy="balanced_norefine"), graph=g)
        ref = api.forward(cfg, params, batch)
        with mesh:
            out = pipeline_logits(cfg, mesh, pl, params, batch,
                                  n_microbatches=4)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 2e-2, err
        print("OK", err)
    """)
    assert "OK" in out


def test_spmd_pipeline_unequal_stage_counts():
    """Force an unbalanced plan (counts differ per stage) — identity
    masking must keep the result exact."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, dataclasses
        from repro import configs
        from repro.configs.common import concrete_batch
        from repro.models import api, lm_graph
        from repro.api import DeploymentSpec
        from repro.api import plan as api_plan
        from repro.launch.pipeline_spmd import (pipeline_logits,
                                                stage_block_counts)
        from repro.launch.mesh import make_mesh

        cfg = dataclasses.replace(configs.get("qwen3-1.7b").smoke_config(),
                                  n_layers=6)
        mesh = make_mesh((1, 4), ("data", "model"))
        params = api.init(cfg, jax.random.PRNGKey(0))
        batch = concrete_batch(cfg, 16, 8, kind="prefill")
        g = lm_graph.lm_layer_graph(cfg, seq_len=16)
        pl = api_plan(DeploymentSpec(stages=4, strategy="comp"),
                      graph=g)            # comp: unequal block counts
        counts = stage_block_counts(pl, cfg.n_layers)
        assert len(set(counts)) > 1, counts
        ref = api.forward(cfg, params, batch)
        with mesh:
            out = pipeline_logits(cfg, mesh, pl, params, batch,
                                  n_microbatches=4)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 2e-2, (err, counts)
        print("OK", counts)
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.configs.common import concrete_batch
        from repro.launch import sharding as shd, steps as steps_lib
        from repro.launch.mesh import make_mesh
        from repro.optim import AdamWConfig

        cfg = configs.get("qwen3-1.7b").smoke_config()
        params, opt = steps_lib.init_train_state(cfg, jax.random.PRNGKey(0))
        batch = concrete_batch(cfg, 16, 4, kind="train")
        step = steps_lib.make_train_step(cfg, AdamWConfig(lr=1e-3),
                                         loss_chunk=16)
        # single-device reference
        p1, o1, m1 = jax.jit(step)(params, opt, batch)
        # sharded
        mesh = make_mesh((2, 2), ("data", "model"))
        with mesh:
            in_sh = (shd.param_shardings(mesh, params, fsdp=True),
                     shd.opt_state_shardings(mesh, opt),
                     shd.batch_shardings(mesh, batch))
            p2, o2, m2 = jax.jit(step, in_shardings=in_sh)(params, opt,
                                                           batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 2e-2, d
        print("OK", float(m1["loss"]), d)
    """)
    assert "OK" in out


def test_mini_dryrun_cell_includes_roofline():
    """End-to-end dryrun_cell on the production mesh for the smallest arch
    (the full sweep runs via python -m repro.launch.dryrun --all)."""
    out = run_with_devices("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import dryrun_cell
        rec = dryrun_cell("whisper-tiny", "decode_32k", multi_pod=False,
                          verbose=False)
        assert rec["status"] == "ok", rec
        assert rec["fits_hbm"]
        assert set(rec["roofline"]) == {"compute_s", "memory_s",
                                        "collective_s", "dominant"}
        assert rec["hlo_flops_per_device"] > 0
        print("OK")
    """, n_devices=512)
    assert "OK" in out


def test_collectives_appear_in_sharded_hlo():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_mesh
        from repro.launch.hlo_analysis import analyze

        mesh = make_mesh((4,), ("model",))
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        with mesh:
            f = jax.jit(lambda a, b: a @ b,
                        in_shardings=(NamedSharding(mesh, P(None, "model")),
                                      NamedSharding(mesh, P("model", None))),
                        out_shardings=NamedSharding(mesh, P()))
            compiled = f.lower(x, w).compile()
        tot = analyze(compiled.as_text())
        assert tot.coll_bytes > 0
        assert sum(tot.coll_counts.values()) >= 1
        print("OK", tot.coll_counts)
    """)
    assert "OK" in out
