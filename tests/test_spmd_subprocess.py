"""Distribution tests that need multiple devices: run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count set locally (the main test
process must keep the real 1-device topology)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int = 4, timeout: int = 560):
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", ""))
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nERR:\n{proc.stderr}"
    return proc.stdout


def test_spmd_pipeline_matches_direct():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.configs.common import concrete_batch
        from repro.models import api, lm_graph
        from repro.api import DeploymentSpec
        from repro.api import plan as api_plan
        from repro.launch.pipeline_spmd import pipeline_logits
        from repro.launch.mesh import make_mesh

        cfg = configs.get("qwen3-1.7b").smoke_config()
        mesh = make_mesh((1, 4), ("data", "model"))
        params = api.init(cfg, jax.random.PRNGKey(0))
        batch = concrete_batch(cfg, 16, 8, kind="prefill")
        g = lm_graph.lm_layer_graph(cfg, seq_len=16)
        pl = api_plan(DeploymentSpec(stages=4,
                                     strategy="balanced_norefine"), graph=g)
        ref = api.forward(cfg, params, batch)
        with mesh:
            out = pipeline_logits(cfg, mesh, pl, params, batch,
                                  n_microbatches=4)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 2e-2, err
        print("OK", err)
    """)
    assert "OK" in out


def test_spmd_pipeline_unequal_stage_counts():
    """Force an unbalanced plan (counts differ per stage) — identity
    masking must keep the result exact."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, dataclasses
        from repro import configs
        from repro.configs.common import concrete_batch
        from repro.models import api, lm_graph
        from repro.api import DeploymentSpec
        from repro.api import plan as api_plan
        from repro.launch.pipeline_spmd import (pipeline_logits,
                                                stage_block_counts)
        from repro.launch.mesh import make_mesh

        cfg = dataclasses.replace(configs.get("qwen3-1.7b").smoke_config(),
                                  n_layers=6)
        mesh = make_mesh((1, 4), ("data", "model"))
        params = api.init(cfg, jax.random.PRNGKey(0))
        batch = concrete_batch(cfg, 16, 8, kind="prefill")
        g = lm_graph.lm_layer_graph(cfg, seq_len=16)
        pl = api_plan(DeploymentSpec(stages=4, strategy="comp"),
                      graph=g)            # comp: unequal block counts
        counts = stage_block_counts(pl, cfg.n_layers)
        assert len(set(counts)) > 1, counts
        ref = api.forward(cfg, params, batch)
        with mesh:
            out = pipeline_logits(cfg, mesh, pl, params, batch,
                                  n_microbatches=4)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 2e-2, (err, counts)
        print("OK", counts)
    """)
    assert "OK" in out


def test_spmd_cnn_executor_matches_direct():
    """CNN GraphModel lowered via apply_subset ranges onto a 4-stage mesh:
    fused per-stage branches + ppermute hops must reproduce model.apply."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models.cnn import synthetic_cnn
        from repro.api import DeploymentSpec
        from repro.api import plan as api_plan
        from repro.launch.pipeline_spmd import SpmdPipelineExecutor

        model = synthetic_cnn(8, L=6, hw=32)
        params = model.init(jax.random.PRNGKey(0))
        pl = api_plan(DeploymentSpec(stages=4,
                                     strategy="balanced_norefine"),
                      graph=model.to_layer_graph())
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32, 3))
        ref = model.apply(params, x)
        with SpmdPipelineExecutor.for_model(model, params, pl,
                                            n_microbatches=4,
                                            batch_size=8) as ex:
            got = ex(x)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_spmd_cnn_2stage_indivisible_batch():
    """2-stage mesh with a batch the microbatch count does not divide:
    the pad-and-slice path must stay exact."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.models.cnn import synthetic_cnn
        from repro.api import DeploymentSpec
        from repro.api import plan as api_plan
        from repro.launch.pipeline_spmd import SpmdPipelineExecutor

        model = synthetic_cnn(4, L=5, hw=16)
        params = model.init(jax.random.PRNGKey(0))
        pl = api_plan(DeploymentSpec(stages=2,
                                     strategy="balanced_norefine"),
                      graph=model.to_layer_graph())
        x = jax.random.normal(jax.random.PRNGKey(1), (7, 16, 16, 3))
        ref = model.apply(params, x)
        with SpmdPipelineExecutor.for_model(model, params, pl,
                                            n_microbatches=4) as ex:
            outs, stats = ex.run_batch(list(x))
        got = jnp.stack(outs)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-4, err
        assert stats["items_per_s"] > 0
        print("OK", err)
    """, n_devices=2)
    assert "OK" in out


def test_spmd_cnn_skip_dag_uneven_plan():
    """Skip connection crossing every cut of an uneven (comp) plan: the
    boundary value must ride through intermediate stages untouched."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro.models.layers import Builder
        from repro.api import DeploymentSpec
        from repro.api import plan as api_plan
        from repro.launch.pipeline_spmd import (SpmdPipelineExecutor,
                                                cnn_boundary_specs)

        b = Builder("skipnet", (16, 16), 3)
        s = b.act(b.conv(b.model.INPUT, 8, 3, name="c1"), name="c1_relu")
        x = s
        for i in range(6):
            x = b.conv(x, 8, 3, name=f"mid{i}")
        x = b.add([x, s], name="skip_add")
        x = b.dense(b.gap(x, name="pool"), 10, name="head")
        model = b.build()

        params = model.init(jax.random.PRNGKey(0))
        pl = api_plan(DeploymentSpec(stages=4, strategy="comp"),
                      graph=model.to_layer_graph())
        bounds, _ = cnn_boundary_specs(model, pl)
        assert any("c1_relu" in dict(bs) for bs in bounds[2:]), bounds
        xin = jax.random.normal(jax.random.PRNGKey(1), (7, 16, 16, 3))
        ref = model.apply(params, xin)
        with SpmdPipelineExecutor.for_model(model, params, pl,
                                            n_microbatches=3,
                                            overlap_streaming=False) as ex:
            got = ex(xin)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 1e-4, err
        print("OK", err)
    """)
    assert "OK" in out


def test_spmd_lm_executor_pad_and_probes():
    """LM executor front-to-back: token batch the microbatch count does
    not divide, plus the predicted/achieved probe surface."""
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.configs.common import concrete_batch
        from repro.models import api, lm_graph
        from repro.api import DeploymentSpec
        from repro.api import plan as api_plan
        from repro.launch.pipeline_spmd import SpmdPipelineExecutor

        cfg = configs.get("qwen3-1.7b").smoke_config()
        params = api.init(cfg, jax.random.PRNGKey(0))
        batch = concrete_batch(cfg, 16, 7, kind="prefill")
        g = lm_graph.lm_layer_graph(cfg, seq_len=16)
        pl = api_plan(DeploymentSpec(stages=4,
                                     strategy="balanced_norefine"), graph=g)
        ref = api.forward(cfg, params, batch)
        with SpmdPipelineExecutor.for_model(cfg, params, pl,
                                            n_microbatches=4,
                                            batch_size=7,
                                            seq_len=16) as ex:
            got = ex(batch["tokens"])
            pred = ex.predicted_stage_times()
            ach = ex.achieved_stage_times(reps=2, warmup=1)
        err = float(jnp.max(jnp.abs(got - ref)))
        assert err < 2e-2, err
        assert len(pred) == len(ach) == 4
        assert all(t > 0 for t in ach)
        assert ex.fill_s > 0
        print("OK", err)
    """)
    assert "OK" in out


def test_stream_stage_weights_overlap_matches_serial():
    """Overlapped and serial streaming must assemble identical global
    arrays (the overlap only reorders transfers against compilation)."""
    out = run_with_devices("""
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.launch.pipeline_spmd import stream_stage_weights

        mesh = make_mesh((1, 4), ("data", "model"))
        rng = np.random.default_rng(0)
        stacked = {"w": rng.standard_normal((4, 64)).astype(np.float32),
                   "b": rng.standard_normal((4, 8)).astype(np.float32)}
        g1, _, r1 = stream_stage_weights(mesh, stacked, "model",
                                         overlap=True)
        g2, _, r2 = stream_stage_weights(mesh, stacked, "model",
                                         overlap=False)
        for k in stacked:
            np.testing.assert_array_equal(np.asarray(g1[k]),
                                          np.asarray(g2[k]))
            assert g1[k].sharding.spec == g2[k].sharding.spec
        assert r1.fill_s > 0 and r2.fill_s > 0
        assert 0 <= r1.blocked_s <= r1.fill_s
        assert 0 <= r2.blocked_s <= r2.fill_s
        print("OK", r1, r2)
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from repro import configs
        from repro.configs.common import concrete_batch
        from repro.launch import sharding as shd, steps as steps_lib
        from repro.launch.mesh import make_mesh
        from repro.optim import AdamWConfig

        cfg = configs.get("qwen3-1.7b").smoke_config()
        params, opt = steps_lib.init_train_state(cfg, jax.random.PRNGKey(0))
        batch = concrete_batch(cfg, 16, 4, kind="train")
        step = steps_lib.make_train_step(cfg, AdamWConfig(lr=1e-3),
                                         loss_chunk=16)
        # single-device reference
        p1, o1, m1 = jax.jit(step)(params, opt, batch)
        # sharded
        mesh = make_mesh((2, 2), ("data", "model"))
        with mesh:
            in_sh = (shd.param_shardings(mesh, params, fsdp=True),
                     shd.opt_state_shardings(mesh, opt),
                     shd.batch_shardings(mesh, batch))
            p2, o2, m2 = jax.jit(step, in_shardings=in_sh)(params, opt,
                                                           batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 2e-2, d
        print("OK", float(m1["loss"]), d)
    """)
    assert "OK" in out


def test_mini_dryrun_cell_includes_roofline():
    """End-to-end dryrun_cell on the production mesh for the smallest arch
    (the full sweep runs via python -m repro.launch.dryrun --all)."""
    out = run_with_devices("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import dryrun_cell
        rec = dryrun_cell("whisper-tiny", "decode_32k", multi_pod=False,
                          verbose=False)
        assert rec["status"] == "ok", rec
        assert rec["fits_hbm"]
        assert set(rec["roofline"]) == {"compute_s", "memory_s",
                                        "collective_s", "dominant"}
        assert rec["hlo_flops_per_device"] > 0
        print("OK")
    """, n_devices=512)
    assert "OK" in out


# ---------------------------------------------------------------------------
# backend routing (in-process: the fallback decision never builds a mesh)
# ---------------------------------------------------------------------------
def _replicated_plan():
    import dataclasses

    from repro.api import DeploymentSpec
    from repro.api import plan as api_plan
    from repro.models.cnn import synthetic_cnn

    pl = api_plan(DeploymentSpec(stages=2, strategy="balanced_norefine"),
                  graph=synthetic_cnn(4, L=4, hw=16).to_layer_graph())
    stages = [dataclasses.replace(pl.stages[0], replicas=2), pl.stages[1]]
    return dataclasses.replace(pl, stages=stages)


def test_spmd_backend_replicated_plan_falls_back_to_host(caplog):
    """Front door: a replicated plan cannot map one-stage-one-mesh-slice;
    executor(backend='spmd') must fall back to the host executor with a
    logged notice, not die."""
    import logging

    from repro.api.deploy import Deployment
    from repro.core.pipeline import PipelineExecutor

    pl = _replicated_plan()
    dep = Deployment.from_plan(pl, stage_fns=[lambda x: x, lambda x: x])
    with caplog.at_level(logging.WARNING, logger="repro.api.deploy"):
        ex = dep.executor(backend="spmd")
    try:
        assert isinstance(ex, PipelineExecutor)
        assert any("falling back" in r.message for r in caplog.records)
    finally:
        ex.stop()


def test_spmd_backend_requires_model_and_params():
    from repro.api import DeploymentSpec
    from repro.api import plan as api_plan
    from repro.api.deploy import Deployment
    from repro.models.cnn import synthetic_cnn

    model = synthetic_cnn(4, L=4, hw=16)
    pl = api_plan(DeploymentSpec(stages=2, strategy="balanced_norefine"),
                  graph=model.to_layer_graph())
    dep = Deployment.from_plan(pl)
    with pytest.raises(ValueError, match="model"):
        dep.executor(backend="spmd")
    with pytest.raises(ValueError, match="'host' or 'spmd'"):
        dep.executor(backend="tpu")


def test_require_unreplicated_direct_raises():
    """The low-level SPMD entry points keep the hard error (only the
    Deployment front door downgrades it to a fallback)."""
    from repro.launch.pipeline_spmd import (_require_unreplicated,
                                            plan_supports_spmd)

    pl = _replicated_plan()
    assert not plan_supports_spmd(pl)
    with pytest.raises(NotImplementedError, match="replicated"):
        _require_unreplicated(pl)


def test_spec_backend_field_round_trips():
    from repro.api import DeploymentSpec

    spec = DeploymentSpec(stages=2, backend="spmd")
    assert DeploymentSpec.from_json(spec.to_json()) == spec
    with pytest.raises(ValueError, match="backend"):
        DeploymentSpec(stages=2, backend="mesh")


def test_collectives_appear_in_sharded_hlo():
    out = run_with_devices("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.launch.mesh import make_mesh
        from repro.launch.hlo_analysis import analyze

        mesh = make_mesh((4,), ("model",))
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        with mesh:
            f = jax.jit(lambda a, b: a @ b,
                        in_shardings=(NamedSharding(mesh, P(None, "model")),
                                      NamedSharding(mesh, P("model", None))),
                        out_shardings=NamedSharding(mesh, P()))
            compiled = f.lower(x, w).compile()
        tot = analyze(compiled.as_text())
        assert tot.coll_bytes > 0
        assert sum(tot.coll_counts.values()) >= 1
        print("OK", tot.coll_counts)
    """)
    assert "OK" in out
