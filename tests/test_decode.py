"""Decode serving tier: costing regimes, KV-aware placement, and the
pipelined decode engine's exact equivalence with the reference
``forward_decode`` path (ISSUE 10)."""
import dataclasses
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.api import DeploymentSpec, PlanReport, plan, resolve_model_graph
from repro.core.edge_tpu_model import EdgeTPUModel, EdgeTPUSpec
from repro.core.segmentation import balanced_split, segment_ranges
from repro.decode.costing import (DecodeCostSource, DecodeOperatingPoint,
                                  decode_depth_costs)
from repro.decode.engine import PipelineDecodeEngine, build_decode_server
from repro.decode.placement import (decode_config_for, kv_budget_bytes,
                                    step_cost_fn)
from repro.models import lm


def _graph_and_cfg(arch):
    return resolve_model_graph(f"lm:{arch}"), decode_config_for(f"lm:{arch}")


# ---------------------------------------------------------------------------
# costing: the per-token regime
# ---------------------------------------------------------------------------
def test_dense_kv_state_grows_with_context():
    g, cfg = _graph_and_cfg("qwen3-1.7b")
    _, s128 = decode_depth_costs(cfg, g, DecodeOperatingPoint(4, 128))
    _, s256 = decode_depth_costs(cfg, g, DecodeOperatingPoint(4, 256))
    blocks = [i for i, s in enumerate(s128) if s > 0]
    assert blocks, "dense model must pin KV state somewhere"
    for i in blocks:
        assert s256[i] == 2 * s128[i]          # KV bytes ~ context
    # per-position KV row: 2 (K+V) * kv_heads * head_dim * itemsize
    row = 2 * cfg.n_kv_heads * cfg.hd * np.dtype(np.float32).itemsize
    assert s128[blocks[0]] == 128 * row


@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "recurrentgemma-9b"])
def test_recurrent_state_is_o1_in_context(arch):
    """rwkv6/rglru blocks pin O(1) state: bytes independent of context."""
    g, cfg = _graph_and_cfg(arch)
    _, s_small = decode_depth_costs(cfg, g, DecodeOperatingPoint(4, 64))
    _, s_big = decode_depth_costs(cfg, g, DecodeOperatingPoint(4, 8192))
    grew = [i for i in range(len(s_small)) if s_big[i] > s_small[i]]
    if cfg.family == "ssm":
        assert not grew                       # pure recurrent: nothing grows
    else:
        # hybrid: only the (window-clamped) attention levels may grow, and
        # only up to the window
        for i in grew:
            assert s_big[i] <= cfg.local_window * 2 * cfg.n_kv_heads \
                * cfg.hd * 4


def test_moe_decode_macs_only_touch_active_experts():
    g, cfg = _graph_and_cfg("phi3.5-moe-42b-a6.6b")
    macs, _ = decode_depth_costs(cfg, g, DecodeOperatingPoint(1, 64))
    params = g.params_per_depth()
    blocks = [i for i, p in enumerate(params)
              if p > cfg.d_model * cfg.vocab]       # the MoE block levels
    assert blocks
    for i in blocks:
        # inactive experts cost memory but not decode compute
        assert macs[i] < params[i]


def test_cost_engine_exposes_segment_state():
    g, cfg = _graph_and_cfg("qwen3-1.7b")
    point = DecodeOperatingPoint(4, 128)
    eng = EdgeTPUModel(g, EdgeTPUSpec(),
                       cost_source=DecodeCostSource(cfg, point)).engine
    assert eng.has_state_costs
    _, state = decode_depth_costs(cfg, g, point)
    # depth ranges are inclusive [lo, hi], matching segment_params
    assert eng.segment_state_bytes(0, g.depth - 1) == sum(state)
    assert eng.segment_state_bytes(0, 0) == state[0]


# ---------------------------------------------------------------------------
# placement: KV cap, never-worse guarantee, report columns
# ---------------------------------------------------------------------------
def test_decode_plan_report_carries_kv_columns():
    g = resolve_model_graph("lm:qwen3-1.7b")
    pl = plan(DeploymentSpec(model="lm:qwen3-1.7b",
                             strategy="decode_placement", stages=3,
                             workload="decode", max_context=128,
                             decode_concurrency=4), graph=g)
    rep = pl.report
    assert rep.is_decode
    assert rep.decode_concurrency == 4 and rep.decode_max_context == 128
    assert rep.decode_tokens_per_s > 0
    assert len(rep.stage_kv_bytes) == pl.n_stages
    assert len(rep.stage_kv_cap_bytes) == pl.n_stages
    budget = kv_budget_bytes(EdgeTPUSpec())
    assert all(cap == budget for cap in rep.stage_kv_cap_bytes)
    assert all(kv <= cap for kv, cap
               in zip(rep.stage_kv_bytes, rep.stage_kv_cap_bytes))
    assert 0.0 <= rep.kv_headroom_pct <= 100.0
    assert "decode" in rep.describe()


@pytest.mark.parametrize("arch,stages,c,ctx", [
    ("qwen3-1.7b", 2, 4, 256),
    ("qwen2.5-14b", 4, 8, 512),
    ("recurrentgemma-9b", 3, 8, 1024),
])
def test_decode_plan_never_worse_than_weight_balanced(arch, stages, c, ctx):
    g, cfg = _graph_and_cfg(arch)
    pl = plan(DeploymentSpec(model=f"lm:{arch}",
                             strategy="decode_placement", stages=stages,
                             workload="decode", max_context=ctx,
                             decode_concurrency=c), graph=g)
    point = DecodeOperatingPoint(c, ctx)
    base = EdgeTPUSpec()
    eng = EdgeTPUModel(g, base,
                       cost_source=DecodeCostSource(cfg, point)).engine
    cost = step_cost_fn(eng, base, point)
    bal = balanced_split(g.params_per_depth(), stages)
    bal_pace = max(cost(lo, hi)
                   for lo, hi in segment_ranges(g.depth, bal))
    if bal_pace != math.inf:
        assert pl.report.decode_tokens_per_s >= c / bal_pace - 1e-9


def test_recurrent_plan_headroom_independent_of_context():
    """An O(1)-state family plans the same at any context: the KV economy
    never binds."""
    g = resolve_model_graph("lm:rwkv6-1.6b")
    reps = []
    for ctx in (128, 8192):
        pl = plan(DeploymentSpec(model="lm:rwkv6-1.6b",
                                 strategy="decode_placement", stages=2,
                                 workload="decode", max_context=ctx,
                                 decode_concurrency=8), graph=g)
        reps.append(pl.report)
    assert reps[0].stage_kv_bytes == reps[1].stage_kv_bytes
    assert reps[0].kv_headroom_pct == pytest.approx(reps[1].kv_headroom_pct)
    assert reps[0].kv_headroom_pct > 99.0


def test_infeasible_operating_point_raises_actionable_error():
    g = resolve_model_graph("lm:qwen3-1.7b")
    with pytest.raises(ValueError, match="lower decode_concurrency"):
        plan(DeploymentSpec(model="lm:qwen3-1.7b",
                            strategy="decode_placement", stages=2,
                            workload="decode", max_context=4096,
                            decode_concurrency=64), graph=g)


def test_auto_stages_scale_out_under_kv_pressure():
    """stages=None picks the smallest KV-feasible stage count — more
    stages than the weight economy alone would ask for."""
    g = resolve_model_graph("lm:qwen3-1.7b")
    pl = plan(DeploymentSpec(model="lm:qwen3-1.7b",
                             strategy="decode_placement", workload="decode",
                             max_context=2048, decode_concurrency=8),
              graph=g)
    assert pl.n_stages > 1
    assert pl.report.decode_tokens_per_s > 0
    assert pl.report.kv_headroom_pct >= 0.0


def test_decode_placement_requires_lm_model_ref():
    g = resolve_model_graph("lm:qwen3-1.7b")
    with pytest.raises(ValueError, match="lm:<arch>"):
        plan(DeploymentSpec(model=None, strategy="decode_placement",
                            stages=2), graph=g)


# ---------------------------------------------------------------------------
# spec validation + JSON round-trips (pinned error text)
# ---------------------------------------------------------------------------
def test_decode_spec_validation_pins():
    with pytest.raises(ValueError, match="workload must be 'batch' or "
                                         "'decode'"):
        DeploymentSpec(stages=2, workload="prefill")
    with pytest.raises(ValueError, match="requires an 'lm:<arch>' model"):
        DeploymentSpec(stages=2, workload="decode", model="cnn:ResNet50")
    with pytest.raises(ValueError, match="max_context must be >= 2"):
        DeploymentSpec(stages=2, workload="decode", model="lm:qwen3-1.7b",
                       max_context=1)
    with pytest.raises(ValueError, match="decode_concurrency must be >= 1"):
        DeploymentSpec(stages=2, workload="decode", model="lm:qwen3-1.7b",
                       decode_concurrency=0)


def test_decode_spec_and_report_round_trip():
    spec = DeploymentSpec(model="lm:qwen3-1.7b",
                          strategy="decode_placement", stages=2,
                          workload="decode", max_context=64,
                          decode_concurrency=2)
    assert DeploymentSpec.from_json(spec.to_json()) == spec
    pl = plan(spec)
    rep = pl.report
    back = PlanReport.from_json(rep.to_json())
    assert back == rep
    assert back.is_decode and back.stage_kv_bytes == rep.stage_kv_bytes
    # a pre-decode report document (no decode keys) still loads
    doc = json.loads(rep.to_json())
    for key in ("decode_tokens_per_s", "decode_concurrency",
                "decode_max_context", "stage_kv_bytes",
                "stage_kv_cap_bytes", "kv_headroom_pct"):
        doc.pop(key)
    old = PlanReport.from_dict(doc)
    assert not old.is_decode


# ---------------------------------------------------------------------------
# engine: exact greedy-token equivalence with forward_decode
# ---------------------------------------------------------------------------
def _reference_greedy(cfg, params, prompt, n_new, max_context):
    """Teacher-force the prompt through forward_decode one token at a
    time, then decode greedily — the sequential reference."""
    cache = lm.init_cache(cfg, 1, max_context)
    logits = None
    for tok in prompt:
        logits, cache = lm.forward_decode(
            cfg, params, jnp.asarray([[tok]], jnp.int32), cache)
    out = []
    tok = int(jnp.argmax(logits[0, -1]))
    for _ in range(n_new):
        out.append(tok)
        logits, cache = lm.forward_decode(
            cfg, params, jnp.asarray([[tok]], jnp.int32), cache)
        tok = int(jnp.argmax(logits[0, -1]))
    return out


@pytest.mark.parametrize("arch,stage_blocks", [
    ("qwen3-1.7b", None),                  # single stage
    ("qwen3-1.7b", "split"),               # two pipeline stages
    ("phi3.5-moe-42b-a6.6b", "split"),
    ("qwen2-vl-72b", None),
])
def test_engine_matches_forward_decode_exactly(arch, stage_blocks):
    cfg = dataclasses.replace(configs.get(arch).smoke_config(),
                              dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    if stage_blocks == "split":
        half = cfg.n_layers // 2
        stage_blocks = [half, cfg.n_layers - half]
    max_context, n_new = 32, 5
    prompt = np.asarray([3, 1, 4, 1, 5, 9], np.int32)
    expect = _reference_greedy(cfg, params, prompt, n_new, max_context)

    engine = PipelineDecodeEngine(cfg, params, n_slots=2,
                                  max_context=max_context,
                                  stage_blocks=stage_blocks)
    with engine:
        # use slot 1 of 2: slot 0 stays inactive (all-masked lanes must
        # not perturb the live one)
        tok = engine.prefill(1, prompt)
        got = [tok]
        ctx = prompt.size + 1
        while len(got) < n_new:
            tok = engine.step([1], [ctx], [tok])[0]
            ctx += 1
            got.append(tok)
    assert got == expect


def test_engine_rejects_bad_shapes():
    cfg = dataclasses.replace(configs.get("qwen3-1.7b").smoke_config(),
                              dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="sum"):
        PipelineDecodeEngine(cfg, params, n_slots=1, max_context=8,
                             stage_blocks=[1])
    eng = PipelineDecodeEngine(cfg, params, n_slots=1, max_context=8)
    with pytest.raises(ValueError, match="out of range"):
        eng.prefill(2, np.asarray([1, 2], np.int32))
    with pytest.raises(ValueError, match="leaves no room"):
        eng.prefill(0, np.arange(8, dtype=np.int32))


def test_build_decode_server_rejects_recurrent_families():
    spec = DeploymentSpec(model="lm:rwkv6-1.6b",
                          strategy="decode_placement", stages=2,
                          workload="decode", max_context=32,
                          decode_concurrency=2)
    with pytest.raises(ValueError, match="no continuous-batching engine"):
        build_decode_server(spec)


def test_deployment_serve_decode_end_to_end():
    """The whole front door: spec -> plan -> Deployment.serve() -> token
    streams, with the plan's cuts becoming engine stages."""
    from repro.api import deploy
    cfg = dataclasses.replace(configs.get("qwen3-1.7b").smoke_config(),
                              dtype=jnp.float32)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    spec = DeploymentSpec(model="lm:qwen3-1.7b",
                          strategy="decode_placement", stages=2,
                          workload="decode", max_context=16,
                          decode_concurrency=2)
    dep = deploy(spec)
    assert dep.plan.n_stages == 2
    with dep.serve(start=True, params=params) as srv:
        assert srv.engine.stage_blocks == [2, 2] or \
            sum(srv.engine.stage_blocks) == cfg.n_layers
        reqs = [srv.submit(np.asarray([2, 7, 1], np.int32),
                           max_new_tokens=3) for _ in range(3)]
        outs = [r.result(timeout=300) for r in reqs]
    assert all(len(o) == 3 for o in outs)
    assert outs[0] == outs[1] == outs[2]       # same prompt, greedy decode
    snap_keyset = {"slot", "rid", "context_len", "kv_bytes"}
    assert srv.engine.kv_bytes_per_token > 0
    assert snap_keyset  # silence lint; snapshot shape covered in sched tests
