"""DecodeScheduler semantics over a scripted fake engine: prefill-join
token order, KV-cap shedding with PR-8 retry hints, and drain-on-stop
(ISSUE 10 satellite)."""
import threading
import time

import numpy as np
import pytest

from repro.core.pipeline import PipelineStopped
from repro.decode.scheduler import DecodeRequest, DecodeScheduler
from repro.serving.server import Overloaded


class FakeEngine:
    """Deterministic decode engine: the first token is ``prompt[0] *
    1000`` and every step increments the last token — each sequence's
    expected stream is a pure function of its prompt, whatever the
    admission interleaving."""

    def __init__(self, n_slots=2, step_delay_s=0.0, gate=None):
        self.n_slots = n_slots
        self.kv_bytes_per_token = 10
        self.step_delay_s = step_delay_s
        self.gate = gate                      # optional Event: block steps
        self.released = []
        self.step_batches = []

    def prefill(self, slot, prompt):
        return int(prompt[0]) * 1000

    def step(self, slots, ctx_lens, last_tokens):
        if self.gate is not None:
            self.gate.wait(timeout=30)
        if self.step_delay_s:
            time.sleep(self.step_delay_s)
        self.step_batches.append(list(slots))
        return [t + 1 for t in last_tokens]

    def release(self, slot):
        self.released.append(slot)


def expected_tokens(prompt, n):
    first = int(prompt[0]) * 1000
    return [first + i for i in range(n)]


def drain_stream(req: DecodeRequest):
    out = []
    while True:
        try:
            out.append(req.stream.get_nowait())
        except Exception:
            return out


# ---------------------------------------------------------------------------
# prefill-join preserves per-sequence token order
# ---------------------------------------------------------------------------
def test_prefill_join_keeps_per_sequence_order():
    eng = FakeEngine(n_slots=2)
    sched = DecodeScheduler(eng, max_context=64, queue_size=16)
    with sched:
        prompts = [np.asarray([i + 1, 7], np.int32) for i in range(5)]
        reqs = [sched.submit(p, max_new_tokens=4) for p in prompts]
        outs = [r.result(timeout=30) for r in reqs]
    for req, prompt, out in zip(reqs, prompts, outs):
        assert out == expected_tokens(prompt, 4)
        pairs = drain_stream(req)
        # stream indices strictly increasing from 0, tokens in order
        assert [i for i, _ in pairs] == list(range(4))
        assert [t for _, t in pairs] == out
    # 5 sequences through 2 slots: joins happened mid-run, and every
    # batched step only carried live slots
    assert all(len(b) <= 2 for b in eng.step_batches)
    assert sorted(eng.released) != []


def test_joining_request_enters_at_token_boundary():
    """A request admitted while another is mid-sequence shares the very
    next batched step (continuous batching, no drain between)."""
    gate = threading.Event()
    gate.set()
    eng = FakeEngine(n_slots=2, step_delay_s=0.01)
    sched = DecodeScheduler(eng, max_context=64, queue_size=16)
    with sched:
        r1 = sched.submit(np.asarray([1], np.int32), max_new_tokens=30)
        time.sleep(0.05)                      # r1 is several steps in
        r2 = sched.submit(np.asarray([2], np.int32), max_new_tokens=5)
        r1.result(timeout=30)
        r2.result(timeout=30)
    assert any(len(b) == 2 for b in eng.step_batches)
    assert r2.tokens == expected_tokens([2], 5)


# ---------------------------------------------------------------------------
# KV-cap shedding: Overloaded + retry hint (PR-8 semantics)
# ---------------------------------------------------------------------------
def test_shed_at_kv_cap_returns_overloaded_with_retry_hint():
    eng = FakeEngine(n_slots=1)
    sched = DecodeScheduler(eng, max_context=64, queue_size=2,
                            backoff_base_s=0.05, backoff_seed=0)
    # not started: the queue fills deterministically
    ok = [sched.submit(np.asarray([1], np.int32)) for _ in range(2)]
    shed1 = sched.submit(np.asarray([2], np.int32))
    shed2 = sched.submit(np.asarray([3], np.int32))
    assert all(not r.done for r in ok)
    for shed in (shed1, shed2):
        assert shed.done
        with pytest.raises(Overloaded) as ei:
            shed.result(timeout=1)
        assert ei.value.rid == shed.rid
        assert ei.value.retry_after_s > 0
        assert ei.value.queue_delay_est_s >= 0
    # consecutive sheds climb the backoff ladder (jitter is <= 25%, the
    # base doubles, so the second hint is strictly larger)
    assert shed2.error.retry_after_s > shed1.error.retry_after_s
    sched.stop()
    for r in ok:
        with pytest.raises(PipelineStopped):
            r.result(timeout=1)


def test_successful_enqueue_resets_backoff_ladder():
    eng = FakeEngine(n_slots=1)
    sched = DecodeScheduler(eng, max_context=64, queue_size=1,
                            backoff_base_s=0.05)
    sched.submit(np.asarray([1], np.int32))            # fills the queue
    first = sched.submit(np.asarray([2], np.int32)).error
    sched.submit(np.asarray([3], np.int32))            # shed again: ladder up
    assert sched._consec_sheds == 2
    with sched._cond:
        sched._pending.clear()                         # queue drains
    sched.submit(np.asarray([4], np.int32))            # accepted
    assert sched._consec_sheds == 0
    again = sched.submit(np.asarray([5], np.int32)).error
    # back at the bottom rung: same magnitude as the first shed
    assert again.retry_after_s < 2 * first.retry_after_s
    sched.stop()


def test_oversized_prompt_rejected_immediately():
    eng = FakeEngine(n_slots=1)
    sched = DecodeScheduler(eng, max_context=8, queue_size=2)
    req = sched.submit(np.arange(8, dtype=np.int32))
    with pytest.raises(ValueError, match="does not fit"):
        req.result(timeout=1)
    sched.stop()


def test_context_cap_truncates_generation():
    """A sequence whose context hits max_context finishes early instead
    of overrunning the cache."""
    eng = FakeEngine(n_slots=1)
    sched = DecodeScheduler(eng, max_context=8, queue_size=2)
    with sched:
        req = sched.submit(np.asarray([1, 2, 3, 4, 5], np.int32),
                           max_new_tokens=100)
        out = req.result(timeout=30)
    # prompt(5) + first token -> ctx 6; steps to ctx 7 then the next
    # token would need ctx 8 == max_context, so generation stops
    assert 1 <= len(out) < 100
    assert out == expected_tokens([1], len(out))


def test_eos_token_stops_sequence():
    eng = FakeEngine(n_slots=1)
    # first token is 1000; eos at 1002 -> exactly 3 tokens emitted
    sched = DecodeScheduler(eng, max_context=64, queue_size=2,
                            eos_token=1002)
    with sched:
        out = sched.submit(np.asarray([1], np.int32),
                           max_new_tokens=50).result(timeout=30)
    assert out == [1000, 1001, 1002]


# ---------------------------------------------------------------------------
# stop(): drain semantics
# ---------------------------------------------------------------------------
def test_drain_completes_in_flight_sequences():
    eng = FakeEngine(n_slots=2, step_delay_s=0.01)
    sched = DecodeScheduler(eng, max_context=64, queue_size=16)
    sched.start()
    reqs = [sched.submit(np.asarray([i + 1], np.int32), max_new_tokens=10)
            for i in range(2)]
    time.sleep(0.03)                          # both admitted, mid-sequence
    sched.stop(drain=True)
    for i, r in enumerate(reqs):
        assert r.result(timeout=1) == expected_tokens([i + 1], 10)


def test_drain_rejects_never_admitted_requests():
    eng = FakeEngine(n_slots=1, step_delay_s=0.01)
    sched = DecodeScheduler(eng, max_context=64, queue_size=16)
    sched.start()
    slow = sched.submit(np.asarray([1], np.int32), max_new_tokens=20)
    time.sleep(0.03)
    queued = [sched.submit(np.asarray([9], np.int32), max_new_tokens=5)
              for _ in range(3)]
    sched.stop(drain=True)
    assert slow.result(timeout=1) == expected_tokens([1], 20)
    for q in queued:
        with pytest.raises(PipelineStopped):
            q.result(timeout=1)


def test_stop_without_drain_fails_active_sequences():
    eng = FakeEngine(n_slots=1, step_delay_s=0.01)
    sched = DecodeScheduler(eng, max_context=64, queue_size=4)
    sched.start()
    req = sched.submit(np.asarray([1], np.int32), max_new_tokens=10_000)
    time.sleep(0.05)
    sched.stop(drain=False)
    with pytest.raises(PipelineStopped):
        req.result(timeout=1)
    assert 0 < len(req.tokens) < 10_000       # partial stream, then cut


def test_stop_before_start_fails_pending():
    eng = FakeEngine(n_slots=1)
    sched = DecodeScheduler(eng, max_context=64, queue_size=4)
    reqs = [sched.submit(np.asarray([1], np.int32)) for _ in range(2)]
    sched.stop()
    for r in reqs:
        with pytest.raises(PipelineStopped):
            r.result(timeout=1)
    # submissions after stop() complete immediately with PipelineStopped
    late = sched.submit(np.asarray([1], np.int32))
    with pytest.raises(PipelineStopped):
        late.result(timeout=1)


def test_start_is_idempotent():
    eng = FakeEngine(n_slots=1)
    sched = DecodeScheduler(eng, max_context=64)
    assert sched.start() is sched.start()
    sched.stop()


# ---------------------------------------------------------------------------
# telemetry: per-slot KV occupancy
# ---------------------------------------------------------------------------
def test_snapshot_reports_slot_kv_occupancy():
    gate = threading.Event()
    eng = FakeEngine(n_slots=2, gate=gate)
    sched = DecodeScheduler(eng, max_context=64, queue_size=16)
    sched.start()
    sched.submit(np.asarray([1, 2, 3], np.int32), max_new_tokens=50)
    deadline = time.time() + 5
    snap = sched.snapshot()
    while not snap["slots"] and time.time() < deadline:
        time.sleep(0.01)
        snap = sched.snapshot()
    assert snap["slots_busy"] == 1 and snap["n_slots"] == 2
    slot = snap["slots"][0]
    # context = prompt(3) + generated so far; KV = context * bytes/token
    assert slot["context_len"] >= 4
    assert slot["kv_bytes"] == slot["context_len"] * eng.kv_bytes_per_token
    assert snap["kv_bytes_total"] == slot["kv_bytes"]
    gate.set()
    sched.stop(drain=False)


def test_snapshot_counts_and_rates_are_deltas():
    eng = FakeEngine(n_slots=2)
    sched = DecodeScheduler(eng, max_context=64, queue_size=16)
    with sched:
        reqs = [sched.submit(np.asarray([i + 1], np.int32),
                             max_new_tokens=3) for i in range(4)]
        for r in reqs:
            r.result(timeout=30)
        snap = sched.snapshot()
        assert snap["admitted"] == 4 and snap["completed"] == 4
        assert snap["tokens"] == 12 and snap["shed"] == 0
        assert snap["tokens_per_s"] > 0
        assert snap["inter_token_p95_s"] >= snap["inter_token_p50_s"] >= 0
        # second snapshot covers an empty window
        snap2 = sched.snapshot()
        assert snap2["tokens"] == 0 and snap2["admitted"] == 0
