"""Analytical Edge TPU model must reproduce the paper's single-TPU and
multi-TPU phenomenology (Figs. 2/4/6/7, Tables 2/4/6)."""
import pytest

from conftest import api_plan as plan
from repro.core import EdgeTPUModel, EdgeTPUSpec, GraphReporter
from repro.core.segmentation import comp_split, balanced_split, segment_ranges
from repro.models.cnn import synthetic_cnn

MIB = 2 ** 20


def model_for(f):
    return EdgeTPUModel(synthetic_cnn(f).to_layer_graph())


def _spill_boundary():
    """Largest f that fits fully on-device, first f that spills."""
    prev = None
    for f in range(380, 700, 10):
        if model_for(f).whole_model_memory().host_bytes > 0:
            return prev, f
        prev = f
    raise AssertionError("no spill found")


def test_fig4_stepped_performance_curve():
    """Throughput collapses when the model crosses the on-chip boundary
    (host spill) — the paper's Fig. 4 signature.  (The modelled drop is
    smaller than the measured one; see EXPERIMENTS.md §Paper-model.)"""
    f_fit, f_spill = _spill_boundary()
    t_fit = model_for(f_fit).single_tpu_tops()
    t_spill = model_for(f_spill).single_tpu_tops()
    assert t_spill < 0.9 * t_fit          # a clear drop at the spill
    # the boundary sits near the 8 MiB on-chip size (paper: ~7-8 MiB)
    size = model_for(f_fit).graph.total_bytes / MIB
    assert 5.0 < size < 8.0


def test_table2_layer_granularity_spill():
    """Host usage jumps in whole-layer (~25%) steps (Table 2)."""
    _, f_spill = _spill_boundary()
    m = model_for(f_spill + 10)           # just past the first drop
    rep = m.whole_model_memory()
    frac = rep.host_bytes / m.graph.total_bytes
    assert 0.10 < frac < 0.35             # ~one of four big layers


def test_segment_memory_zero_host_when_fits():
    m = model_for(480)
    cuts = balanced_split(m.graph.params_per_depth(), 2)
    for lo, hi in segment_ranges(m.graph.depth, cuts):
        assert m.segment_memory(lo, hi).host_bytes == 0


def test_fig6_comp_split_keeps_host_usage():
    """SEGM_COMP on 4 TPUs still spills for some synthetic models that
    balanced segmentation fits (paper Table 4, right columns)."""
    found = False
    for f in range(560, 760, 20):
        m = model_for(f)
        P = m.graph.params_per_depth()
        comp_spills = any(r.host_bytes > 0
                          for r in m.stage_memories(comp_split(P, 4)))
        bal_spills = any(r.host_bytes > 0
                         for r in m.stage_memories(balanced_split(P, 4)))
        if comp_spills and not bal_spills:
            found = True
            break
    assert found, "no synthetic size where comp spills but balanced fits"


def test_balanced_speedup_beats_comp_synthetic():
    """Fig. 6 vs Fig. 7: balanced > comp for spilling synthetic models."""
    m = model_for(700)                    # ~17 MiB: host spill on 1 TPU
    P = m.graph.params_per_depth()
    sp_bal = m.speedup(balanced_split(P, 4), batch=15)
    sp_comp = m.speedup(comp_split(P, 4), batch=15)
    assert sp_bal > sp_comp
    assert sp_bal > 3.0                   # near-linear at minimum


def test_table7_superlinear_speedup_real_models():
    """Paper Table 7 headline: on real CNNs, SEGM_BALANCED beats a single
    TPU super-linearly (ResNet101), and near-linearly at worst for the
    deepest models whose first stage is MAC-heavy (ResNet152; the
    beyond-paper cost-balanced planner closes that gap — see
    benchmarks/segm_real.py)."""
    from repro.core.placement import min_stages_no_spill
    from repro.models.cnn import REAL_CNNS
    for name, floor in (("ResNet101", 1.0), ("ResNet152", 0.85),
                        ("DenseNet121", 1.0)):
        g = REAL_CNNS[name]().to_layer_graph()
        m = EdgeTPUModel(g)
        n = min_stages_no_spill(g, m)
        pl = plan(g, n, "balanced", tpu_model=m)
        sp = m.speedup(pl.cuts, batch=15)
        assert sp > floor * n, (name, n, sp)


def test_prof_equals_balanced_on_synthetic():
    """Paper §6.2: for the synthetic family the balanced scheme finds the
    same partition the exhaustive profiler picks."""
    m = model_for(560)
    pl_b = plan(m.graph, 4, "balanced", tpu_model=m)
    pl_p = plan(m.graph, 4, "prof", tpu_model=m)
    t_b = m.pipeline_batch_time(pl_b.cuts)
    t_p = m.pipeline_batch_time(pl_p.cuts)
    assert t_b <= t_p * 1.001


def test_peak_tops_bound():
    spec = EdgeTPUSpec()
    m = model_for(200)
    assert m.single_tpu_tops() < spec.peak_tops
