"""CNN zoo: Table 1 fidelity (params/MACs) + runnable forwards + pipelined
subset execution == direct forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import api_plan as plan
from repro.core import EdgeTPUModel
from repro.core.pipeline import PipelineExecutor
from repro.models.cnn import REAL_CNNS, TABLE1, synthetic_cnn
from repro.models.layers import GraphModel

# NASNetMobile is a flagged structural approximation (params match, MACs
# deviate); V2 ResNets share V1 MAC structure in our builders.
MACS_EXEMPT = {"NASNetMobile", "ResNet50V2", "ResNet101V2", "ResNet152V2"}


@pytest.mark.parametrize("name", sorted(REAL_CNNS))
def test_table1_params(name):
    m = REAL_CNNS[name]()
    ref_p, _ = TABLE1[name]
    rel = abs(m.total_params / 1e6 - ref_p) / ref_p
    assert rel < 0.08, f"{name}: {m.total_params/1e6:.2f}M vs {ref_p}M"


@pytest.mark.parametrize("name", sorted(set(REAL_CNNS) - MACS_EXEMPT))
def test_table1_macs(name):
    m = REAL_CNNS[name]()
    _, ref_m = TABLE1[name]
    rel = abs(m.total_macs / 1e6 - ref_m) / ref_m
    assert rel < 0.12, f"{name}: {m.total_macs/1e6:.0f} vs {ref_m} MMACs"


def test_synthetic_param_formula():
    for f, L in ((32, 5), (100, 5), (64, 3)):
        m = synthetic_cnn(f, L=L)
        assert m.total_params == 9 * f * (3 + f * (L - 1))


def test_synthetic_forward_shapes_and_finite():
    m = synthetic_cnn(16)
    params = m.init(jax.random.PRNGKey(0))
    x = jnp.ones((2, 64, 64, 3))
    y = m.apply(params, x)
    assert y.shape == (2, 64, 64, 16)
    assert np.isfinite(np.asarray(y)).all()


def test_mobilenet_forward():
    m = REAL_CNNS["MobileNetV2"]()
    params = m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 224, 224, 3))
    y = m.apply(params, x)
    assert y.shape == (1, 1000)
    assert np.isfinite(np.asarray(y)).all()


def _pipeline_vs_direct(model: GraphModel, n_stages: int):
    g = model.to_layer_graph()
    pl = plan(g, n_stages, "balanced_norefine")
    params = model.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (1,) + model.input_shape)
    direct = model.apply(params, x)

    def stage_fn(layers):
        def run(boundary):
            return model.apply_subset(params, boundary, layers)
        return run

    fns = [stage_fn(layers) for layers in pl.stage_layers]
    execu = PipelineExecutor(fns)
    outs, _ = execu.run_batch([{GraphModel.INPUT: x}])
    np.testing.assert_allclose(np.asarray(outs[0][model.output]),
                               np.asarray(direct), rtol=2e-4, atol=2e-4)


def test_pipelined_chain_model_equals_direct():
    _pipeline_vs_direct(synthetic_cnn(12, hw=32), 3)


def test_pipelined_branchy_model_equals_direct():
    """Horizontal cuts must be correct across open paths (paper Fig. 8):
    use a small inception-style builder with 4-way branches."""
    from repro.models.layers import Builder
    b = Builder("mini_inception", (32, 32), 3)
    x = b.conv_bn(b.model.INPUT, 8, 3, 1, "same", "relu", "stem")
    for i in range(3):
        b1 = b.conv_bn(x, 8, 1, 1, "same", "relu", f"m{i}_b1")
        b2 = b.conv_bn(x, 6, 1, 1, "same", "relu", f"m{i}_b2a")
        b2 = b.conv_bn(b2, 8, 3, 1, "same", "relu", f"m{i}_b2b")
        b3 = b.pool(x, "avg", 3, 1, "same", f"m{i}_b3p")
        b3 = b.conv_bn(b3, 8, 1, 1, "same", "relu", f"m{i}_b3")
        x = b.concat([b1, b2, b3], f"m{i}_cat")
    x = b.gap(x, "gap")
    b.dense(x, 10, name="head")
    _pipeline_vs_direct(b.build(), 4)


def test_min_stages_matches_paper_table5():
    """Paper Table 5: ceil(size/8MiB) — e.g. ResNet101 -> 6, ResNet152 -> 8,
    InceptionV4 -> 7, Xception -> 4 (int8 bytes == param count)."""
    from repro.core.placement import min_stages_to_fit
    expect = {"ResNet101": 6, "ResNet152": 8, "InceptionV4": 7,
              "Xception": 3, "DenseNet121": 2}
    for name, n in expect.items():
        g = REAL_CNNS[name]().to_layer_graph()
        got = min_stages_to_fit(g, 8 * 2 ** 20)
        assert abs(got - n) <= 1, (name, got, n)
