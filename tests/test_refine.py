"""Refinement (paper §6.1.3) tests: convergence, sweep directions, the
multi-position optimization, and per-stage device limits (heterogeneous
topologies)."""
import pytest

from conftest import api_plan as plan
from repro.core import (DeviceSpec, EdgeTPUModel, GraphReporter, Topology,
                        refine_cuts)
from repro.core.graph import chain_graph
from repro.core.segmentation import balanced_split, segment_ranges
from repro.core.topology import TopologyCostModel
from repro.models.cnn import REAL_CNNS

MIB = 2 ** 20


class DictReporter:
    """Reporter with an arbitrary per-depth byte table + fixed capacity."""

    def __init__(self, sizes, capacity):
        self.sizes = sizes
        self.capacity = capacity
        self.calls = 0

    def segment_report(self, lo, hi):
        self.calls += 1
        used = sum(self.sizes[lo:hi + 1])
        return min(used, self.capacity), max(0, used - self.capacity)

    def depth_bytes(self, d):
        return self.sizes[d]


def test_forward_sweep_fixes_overflowing_first_segment():
    sizes = [60, 10, 10, 10, 10]          # params-balanced puts cut late
    cap = 65
    cuts = [1, 2, 3]                      # S0 = 70 > cap
    res = refine_cuts(cuts, 5, DictReporter(sizes, cap))
    assert res.converged
    rep = DictReporter(sizes, cap)
    for lo, hi in zip([0] + [c + 1 for c in res.cuts], res.cuts + [4]):
        assert rep.segment_report(lo, hi)[1] == 0


def test_backward_sweep_needed_for_last_segment():
    """Forward sweeps push layers toward the last segment; when the last
    one overflows, the backward sweep must pull cuts later."""
    sizes = [10, 10, 10, 10, 60]
    cap = 65
    cuts = [0, 1, 2]                      # last segment 10+60=70 > cap
    res = refine_cuts(cuts, 5, DictReporter(sizes, cap))
    assert res.converged


def test_multi_step_saves_compilations():
    sizes = [5] * 40 + [100]
    cap = 110
    cuts = [9, 19, 29]                    # last segment 55+100 > cap
    fast = refine_cuts(cuts, 41, DictReporter(sizes, cap), multi_step=True)
    slow = refine_cuts(cuts, 41, DictReporter(sizes, cap), multi_step=False)
    assert fast.converged and slow.converged
    assert fast.compilations <= slow.compilations


def test_unsatisfiable_does_not_loop_forever():
    sizes = [100, 100, 100]
    res = refine_cuts([0, 1], 3, DictReporter(sizes, capacity=50),
                      max_rounds=3)
    assert not res.converged              # impossible; must terminate


def test_single_stage_graph_converges_when_it_fits():
    """s=1 (no cuts): nothing to sweep, converged iff the whole model
    fits."""
    res = refine_cuts([], 5, DictReporter([10] * 5, capacity=100))
    assert res.converged and res.cuts == [] and res.moves == 0


def test_single_stage_graph_reports_nonconvergence():
    res = refine_cuts([], 5, DictReporter([10] * 5, capacity=30),
                      max_rounds=3)
    assert not res.converged and res.cuts == []


def test_backward_sweep_when_last_segment_spills_multi_stage():
    """Satellite case: forward sweeps leave the LAST segment over
    capacity; the backward sweep must shed its leading depths leftward
    across several cuts."""
    sizes = [10, 10, 10, 10, 30, 40]
    cap = 45
    cuts = [0, 1]                          # last segment 10+10+30+40 > cap
    res = refine_cuts(cuts, 6, DictReporter(sizes, cap))
    assert res.converged
    rep = DictReporter(sizes, cap)
    for lo, hi in segment_ranges(6, res.cuts):
        assert rep.segment_report(lo, hi)[1] == 0


def test_nonconverging_reporter_terminates_with_flag():
    """A reporter that always claims a spill must produce
    converged=False within max_rounds rather than hang."""

    class AlwaysSpills:
        def segment_report(self, lo, hi):
            return 0, 1                    # every segment "spills" 1 byte

        def depth_bytes(self, d):
            return 1

    res = refine_cuts([2, 5], 9, AlwaysSpills(), max_rounds=4)
    assert not res.converged
    assert res.compilations > 0


def test_reporter_argument_validation():
    rep = DictReporter([10, 10], 100)
    with pytest.raises(ValueError):
        refine_cuts([0], 2)                          # neither
    with pytest.raises(ValueError):
        refine_cuts([0], 2, rep, stage_reporters=[rep, rep])   # both
    with pytest.raises(ValueError):
        refine_cuts([0], 2, stage_reporters=[rep])   # wrong count


def test_per_stage_limits_heterogeneous_capacities():
    """Per-stage device limits: the same cut list converges only when each
    stage is judged against its own device's capacity."""
    sizes = [30, 30, 30, 40]
    small = DictReporter(sizes, capacity=50)
    big = DictReporter(sizes, capacity=100)
    # homogeneous small devices: no cut fits both stages under cap 50
    res_small = refine_cuts([1], 4, small, max_rounds=3)
    assert not res_small.converged
    # big device first, small second: shed depth onto the big one
    res_het = refine_cuts([1], 4, stage_reporters=[big, small])
    assert res_het.converged
    (lo0, hi0), (lo1, hi1) = segment_ranges(4, res_het.cuts)
    assert big.segment_report(lo0, hi0)[1] == 0
    assert small.segment_report(lo1, hi1)[1] == 0


def test_per_stage_limits_with_device_specs():
    """End-to-end: TopologyCostModel.stage_reporters binds each refine
    stage to its DeviceSpec's on-chip capacity."""
    mib = MIB
    layers = [(f"l{i}", 2 * mib, 1000, 1024) for i in range(8)]  # 16 MiB
    g = chain_graph("het", layers)
    # one 12-MiB device + one default 8-MiB device: balanced halves (8 MiB
    # each) fit the big device but spill the small one's ~7.9 MiB capacity;
    # per-stage refinement shifts depth onto the big device and converges
    big = DeviceSpec(name="big", onchip_bytes=12 * mib)
    topo = Topology(devices=(big, DeviceSpec()))
    tcm = TopologyCostModel(g, topo)
    reporters = tcm.stage_reporters(topo.devices)
    cuts = balanced_split(g.params_per_depth(), 2)
    res = refine_cuts(cuts, g.depth, stage_reporters=reporters)
    assert res.converged
    (lo0, hi0), (lo1, hi1) = segment_ranges(g.depth, res.cuts)
    assert reporters[0].segment_report(lo0, hi0)[1] == 0
    assert reporters[1].segment_report(lo1, hi1)[1] == 0
    assert (hi0 - lo0) > (hi1 - lo1)       # big device holds more depth
    # the same plan judged against two default devices does not converge
    small_reporter = GraphReporter(EdgeTPUModel(g))
    res_small = refine_cuts(cuts, g.depth, small_reporter, max_rounds=3)
    assert not res_small.converged


def test_paper_claim_balanced_avoids_host_on_all_real_models():
    """Paper §6.2: 'SEGM_BALANCED manages to avoid the use of host memory
    in all models' at the paper's TPU-count rule (§5.2.2: minimum count
    that ideally avoids host memory), and that count is close to the
    paper's Table 5 choice."""
    from repro.core.placement import min_stages_no_spill
    paper_n = {"ResNet50": 4, "ResNet101": 6, "InceptionV3": 4,
               "DenseNet169": 3, "ResNet152": 8}
    for name, expect in paper_n.items():
        g = REAL_CNNS[name]().to_layer_graph()
        model = EdgeTPUModel(g)
        n = min_stages_no_spill(g, model)
        pl = plan(g, n, "balanced", tpu_model=model)
        mems = model.stage_memories(pl.cuts)
        assert all(m.host_bytes == 0 for m in mems), name
        assert abs(n - expect) <= 1, (name, n, expect)


def test_refinement_only_when_needed():
    """§6.2: refinement ran for only 5/15 real models; balanced_norefine
    must already avoid host memory for most."""
    from repro.core.placement import min_stages_no_spill
    clean = 0
    names = ("ResNet50", "ResNet101", "DenseNet121", "InceptionV3",
             "MobileNet")
    for name in names:
        g = REAL_CNNS[name]().to_layer_graph()
        model = EdgeTPUModel(g)
        n = min_stages_no_spill(g, model)
        pl = plan(g, n, "balanced_norefine")
        if all(m.host_bytes == 0 for m in model.stage_memories(pl.cuts)):
            clean += 1
    assert clean >= len(names) - 2
