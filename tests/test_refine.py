"""Refinement (paper §6.1.3) tests: convergence, sweep directions, the
multi-position optimization."""
from repro.core import EdgeTPUModel, GraphReporter, plan, refine_cuts
from repro.core.graph import chain_graph
from repro.core.segmentation import balanced_split
from repro.models.cnn import REAL_CNNS

MIB = 2 ** 20


class DictReporter:
    """Reporter with an arbitrary per-depth byte table + fixed capacity."""

    def __init__(self, sizes, capacity):
        self.sizes = sizes
        self.capacity = capacity
        self.calls = 0

    def segment_report(self, lo, hi):
        self.calls += 1
        used = sum(self.sizes[lo:hi + 1])
        return min(used, self.capacity), max(0, used - self.capacity)

    def depth_bytes(self, d):
        return self.sizes[d]


def test_forward_sweep_fixes_overflowing_first_segment():
    sizes = [60, 10, 10, 10, 10]          # params-balanced puts cut late
    cap = 65
    cuts = [1, 2, 3]                      # S0 = 70 > cap
    res = refine_cuts(cuts, 5, DictReporter(sizes, cap))
    assert res.converged
    rep = DictReporter(sizes, cap)
    for lo, hi in zip([0] + [c + 1 for c in res.cuts], res.cuts + [4]):
        assert rep.segment_report(lo, hi)[1] == 0


def test_backward_sweep_needed_for_last_segment():
    """Forward sweeps push layers toward the last segment; when the last
    one overflows, the backward sweep must pull cuts later."""
    sizes = [10, 10, 10, 10, 60]
    cap = 65
    cuts = [0, 1, 2]                      # last segment 10+60=70 > cap
    res = refine_cuts(cuts, 5, DictReporter(sizes, cap))
    assert res.converged


def test_multi_step_saves_compilations():
    sizes = [5] * 40 + [100]
    cap = 110
    cuts = [9, 19, 29]                    # last segment 55+100 > cap
    fast = refine_cuts(cuts, 41, DictReporter(sizes, cap), multi_step=True)
    slow = refine_cuts(cuts, 41, DictReporter(sizes, cap), multi_step=False)
    assert fast.converged and slow.converged
    assert fast.compilations <= slow.compilations


def test_unsatisfiable_does_not_loop_forever():
    sizes = [100, 100, 100]
    res = refine_cuts([0, 1], 3, DictReporter(sizes, capacity=50),
                      max_rounds=3)
    assert not res.converged              # impossible; must terminate


def test_paper_claim_balanced_avoids_host_on_all_real_models():
    """Paper §6.2: 'SEGM_BALANCED manages to avoid the use of host memory
    in all models' at the paper's TPU-count rule (§5.2.2: minimum count
    that ideally avoids host memory), and that count is close to the
    paper's Table 5 choice."""
    from repro.core.planner import min_stages_no_spill
    paper_n = {"ResNet50": 4, "ResNet101": 6, "InceptionV3": 4,
               "DenseNet169": 3, "ResNet152": 8}
    for name, expect in paper_n.items():
        g = REAL_CNNS[name]().to_layer_graph()
        model = EdgeTPUModel(g)
        n = min_stages_no_spill(g, model)
        pl = plan(g, n, "balanced", tpu_model=model)
        mems = model.stage_memories(pl.cuts)
        assert all(m.host_bytes == 0 for m in mems), name
        assert abs(n - expect) <= 1, (name, n, expect)


def test_refinement_only_when_needed():
    """§6.2: refinement ran for only 5/15 real models; balanced_norefine
    must already avoid host memory for most."""
    from repro.core.planner import min_stages_no_spill
    clean = 0
    names = ("ResNet50", "ResNet101", "DenseNet121", "InceptionV3",
             "MobileNet")
    for name in names:
        g = REAL_CNNS[name]().to_layer_graph()
        model = EdgeTPUModel(g)
        n = min_stages_no_spill(g, model)
        pl = plan(g, n, "balanced_norefine")
        if all(m.host_bytes == 0 for m in model.stage_memories(pl.cuts)):
            clean += 1
    assert clean >= len(names) - 2
