"""LM LayerGraph + planner tests: the paper's technique on the assigned
archs (embed/head imbalance is what SEGM_BALANCED fixes)."""
import pytest

from repro import configs
from conftest import api_plan as plan
from repro.core.placement import min_stages_to_fit
from repro.core.segmentation import segment_sums
from repro.models import api
from repro.models.lm_graph import lm_layer_graph

ARCHS = configs.arch_ids()


@pytest.mark.parametrize("arch", ARCHS)
def test_lm_graph_params_match_eval_shape(arch):
    cfg = configs.get(arch).config()
    g = lm_layer_graph(cfg, seq_len=4096)
    total = api.param_count(cfg)
    assert abs(g.total_params - total) / total < 1e-6, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_lm_graph_structure(arch):
    cfg = configs.get(arch).config()
    g = lm_layer_graph(cfg)
    if cfg.family == "encdec":
        # cross-attn edges put every decoder layer after the encoder
        d = g.depths()
        assert d["dec_0"] > d[f"enc_{cfg.n_enc_layers - 1}"]
    else:
        assert g.depth == cfg.n_layers + 3   # embed + blocks + norm + head


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "minitron-4b",
                                  "qwen2.5-14b", "granite-moe-1b-a400m"])
def test_balanced_beats_comp_on_embed_heavy_archs(arch):
    """The vendor-style equal-layer-count split overloads the embed/head
    stages; Algorithm 1 must strictly reduce the max stage size."""
    cfg = configs.get(arch).config()
    g = lm_layer_graph(cfg)
    comp = plan(g, 8, "comp")
    bal = plan(g, 8, "balanced_norefine")
    # the pipeline is paced by the largest stage: Algorithm 1 minimizes it
    assert max(bal.stage_params) < max(comp.stage_params), arch


def test_qwen3_embed_dominates_blocks():
    """qwen3-1.7b: tied embedding ~311M params vs ~54M per block — the
    strongest imbalance case in the pool (DESIGN.md §6)."""
    cfg = configs.get("qwen3-1.7b").config()
    g = lm_layer_graph(cfg)
    P = g.params_per_depth()
    embed, blocks = P[0], P[1:-2]
    assert embed > 5 * max(blocks)
    bal = plan(g, 8, "balanced_norefine")
    # balanced split gives the embed stage zero or very few blocks
    embed_stage_layers = bal.stage_layers[0]
    assert sum(1 for l in embed_stage_layers if l.startswith("block_")) <= 2


def test_min_stages_to_fit_lm():
    cfg = configs.get("qwen2.5-14b").config()
    g = lm_layer_graph(cfg)
    # 14.77B bf16 ~= 29.5 GB; 16 GiB/chip budget -> 2 chips min
    assert min_stages_to_fit(g, 16 * 2 ** 30) == 2


@pytest.mark.parametrize("arch", ARCHS)
def test_plan_covers_all_layers_exactly_once(arch):
    cfg = configs.get(arch).config()
    g = lm_layer_graph(cfg)
    pl = plan(g, 4, "balanced_norefine")
    seen = [l for layers in pl.stage_layers for l in layers]
    assert sorted(seen) == sorted(g.nodes.keys())
    assert sum(pl.stage_params) == g.total_params
