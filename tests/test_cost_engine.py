"""SegmentCostEngine fast path + "opt" minimax DP planner tests.

The engine must be *bit-identical* to the naive EdgeTPUModel walks (it is the
same arithmetic over precomputed prefix sums), and the "opt" strategy must
never exceed the balanced plan's max modeled stage time — verified against
the exact O(d²·s) DP oracle."""
import random

import pytest

from conftest import api_plan as plan
from repro.core import EdgeTPUModel, LayerGraph, chain_graph
from repro.core.cost_engine import SegmentCostEngine
from repro.core.segmentation import minimax_time_split, segment_ranges
from repro.models.cnn import REAL_CNNS, synthetic_cnn

ZOO_SAMPLE = ("ResNet50", "InceptionV3", "DenseNet121")


@pytest.fixture(scope="module", params=ZOO_SAMPLE + ("synthetic700",))
def model_pair(request):
    if request.param == "synthetic700":
        g = synthetic_cnn(700).to_layer_graph()
    else:
        g = REAL_CNNS[request.param]().to_layer_graph()
    return EdgeTPUModel(g), EdgeTPUModel(g, use_engine=False)


# ---------------------------------------------------------------------------
# engine == naive, bit for bit
# ---------------------------------------------------------------------------
def test_engine_matches_naive_over_random_segments(model_pair):
    fast, naive = model_pair
    d = fast.graph.depth
    rng = random.Random(1234)
    for _ in range(100):
        lo = rng.randrange(d)
        hi = rng.randrange(lo, d)
        assert fast.segment_time(lo, hi) == naive.segment_time(lo, hi)
        mf = fast.segment_memory(lo, hi)
        mn = naive.segment_memory(lo, hi)
        assert mf.device_bytes == mn.device_bytes
        assert mf.host_bytes == mn.host_bytes
        assert mf.layer_placement == mn.layer_placement


def test_engine_range_sums_and_max_activation(model_pair):
    fast, _ = model_pair
    g = fast.graph
    eng = fast.engine
    P = g.params_per_depth()
    levels = g.levels()
    d = g.depth
    rng = random.Random(7)
    for _ in range(50):
        lo = rng.randrange(d)
        hi = rng.randrange(lo, d)
        assert eng.segment_params(lo, hi) == sum(P[lo:hi + 1])
        want_act = max((g.nodes[n].out_bytes
                        for lvl in levels[lo:hi + 1] for n in lvl), default=0)
        assert eng.segment_max_activation(lo, hi) == want_act


def test_engine_bytes_only_report_matches_full_report(model_pair):
    fast, _ = model_pair
    d = fast.graph.depth
    for lo, hi in ((0, d - 1), (0, d // 2), (d // 3, 2 * d // 3)):
        rep = fast.segment_memory(lo, hi)
        assert fast.segment_report_bytes(lo, hi) == (rep.device_bytes,
                                                     rep.host_bytes)


# ---------------------------------------------------------------------------
# graph-level caching (satellite: per-depth arrays cached + invalidated)
# ---------------------------------------------------------------------------
def test_graph_cache_returns_same_object_and_invalidates():
    g = chain_graph("c", [("a", 10, 1, 4), ("b", 20, 1, 4)])
    first = g.out_bytes_per_depth()
    assert g.out_bytes_per_depth() is first          # cached
    assert g.params_per_depth() is g.params_per_depth()
    g.add_layer("c", params=30, macs=1, out_bytes=4, inputs=["b"])
    assert g.params_per_depth() == [10, 20, 30]      # invalidated on add
    assert len(g.out_bytes_per_depth()) == 3


def test_graph_cache_disabled_recomputes():
    g = LayerGraph("nc", cache=False)
    g.add_layer("a", params=1)
    g.add_layer("b", params=2, inputs=["a"])
    assert g.params_per_depth() is not g.params_per_depth()
    assert g.params_per_depth() == [1, 2]


# ---------------------------------------------------------------------------
# "opt": exact time-balanced minimax DP
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ZOO_SAMPLE)
@pytest.mark.parametrize("s", [4, 6])
def test_opt_never_worse_than_balanced(name, s):
    g = REAL_CNNS[name]().to_layer_graph()
    m = EdgeTPUModel(g)
    po = plan(g, s, "opt", tpu_model=m)
    pb = plan(g, s, "balanced", tpu_model=m)
    assert max(m.stage_times(po.cuts)) <= max(m.stage_times(pb.cuts)) + 1e-15


@pytest.mark.parametrize("name", ZOO_SAMPLE)
def test_opt_within_oracle_bound(name):
    """dp_split-style oracle: the exact O(d²·s) DP lower-bounds the fast
    path; the fast path must sit between the oracle and balanced."""
    g = REAL_CNNS[name]().to_layer_graph()
    m = EdgeTPUModel(g)
    s = 4
    fast_cuts = minimax_time_split(g.depth, s, m.segment_time)
    exact_cuts = minimax_time_split(g.depth, s, m.segment_time, exact=True)
    t_fast = max(m.stage_times(fast_cuts))
    t_exact = max(m.stage_times(exact_cuts))
    t_bal = max(m.stage_times(plan(g, s, "balanced", tpu_model=m).cuts))
    assert t_exact <= t_fast + 1e-15
    assert min(t_fast, t_bal) <= t_bal          # opt strategy takes the min
    # the crossing-point search stays within a few percent of the optimum
    assert t_fast <= 1.05 * t_exact


def test_opt_plan_structure_invariants():
    g = REAL_CNNS["ResNet50"]().to_layer_graph()
    pl = plan(g, 5, "opt")
    assert pl.n_stages == 5
    assert len(pl.cuts) == 4 and pl.cuts == sorted(set(pl.cuts))
    seen = [l for layers in pl.stage_layers for l in layers]
    assert sorted(seen) == sorted(g.nodes.keys())
    assert sum(pl.stage_params) == g.total_params


def test_minimax_time_split_degenerate_and_validation():
    cost = lambda lo, hi: float(hi - lo + 1)
    assert minimax_time_split(5, 1, cost) == []
    cuts = minimax_time_split(6, 6, cost)
    assert cuts == [0, 1, 2, 3, 4]              # all singleton segments
    with pytest.raises(ValueError):
        minimax_time_split(3, 4, cost)
    with pytest.raises(ValueError):
        minimax_time_split(3, 0, cost)


def test_minimax_time_split_exact_on_additive_chain():
    """On a purely additive cost the DP must reproduce the known minimax
    partition of the underlying array."""
    P = [5, 1, 9, 2, 2, 7, 3]
    prefix = [0]
    for p in P:
        prefix.append(prefix[-1] + p)
    cost = lambda lo, hi: float(prefix[hi + 1] - prefix[lo])
    for s in (2, 3, 4):
        cuts = minimax_time_split(len(P), s, cost)
        ranges = segment_ranges(len(P), cuts)
        got = max(sum(P[lo:hi + 1]) for lo, hi in ranges)
        exact = minimax_time_split(len(P), s, cost, exact=True)
        want = max(sum(P[lo:hi + 1])
                   for lo, hi in segment_ranges(len(P), exact))
        assert got == want
