"""Fault-tolerance tier tests (ISSUE 6): replica failover with in-flight
re-dispatch, stage-loss fail-fast + degraded-mode replanning through
HealthMonitor -> ElasticPlanner -> reconfigure(), hedged dispatch
(off by default, bit-identical outputs), the chaos harness's
exactly-once audit, reconfigure under concurrent submitters, and the
runtime satellites (SpeculativeExecutor, TrainSupervisor,
FailureInjector).  All seeds fixed — this file runs in tier-1 CI."""
import tempfile
import threading
import time

import pytest

from repro.checkpoint import CheckpointStore
from repro.core.pipeline import (PipelineExecutor, ReplicaFailure,
                                 StageLost)
from repro.models.cnn import synthetic_cnn
from repro.runtime import (ChaosEvent, ChaosMonkey, ElasticPlanner,
                           FailureInjector, FaultPolicy, HealthMonitor,
                           SpeculativeExecutor, TrainSupervisor,
                           replica_kill_schedule, run_chaos_executor)
from repro.serving import PipelinedModelServer
from conftest import api_plan as plan


# ---------------------------------------------------------------------------
# executor failover: in-flight re-dispatch, order preserved
# ---------------------------------------------------------------------------
def test_replica_failure_redispatches_in_flight():
    """A replica that dies mid-stream (ReplicaFailure out of the stage fn)
    hands its accepted-but-unfinished envelopes to survivors; every
    request completes, in submission order."""
    inj = FailureInjector(fail_at_steps=[5], exc_type=ReplicaFailure)

    def work(x):
        time.sleep(0.001)
        return x * 2

    fns = [lambda x: x + 0, inj.wrap(work, "mid"), lambda x: x + 1]
    with PipelineExecutor(fns, replicas=[1, 3, 1]) as ex:
        futs = [ex.submit(i) for i in range(40)]
        assert [f.result(timeout=20) for f in futs] == \
            [i * 2 + 1 for i in range(40)]
        h = ex.health_snapshot()
    assert sum(h["live_replicas"]) == 4          # one replica retired
    assert sum(h["redispatches"]) >= 1


def test_external_kill_replica_under_load():
    def slow(x):
        time.sleep(0.002)
        return x

    with PipelineExecutor([slow], replicas=[3]) as ex:
        futs = [ex.submit(i) for i in range(30)]
        time.sleep(0.01)
        ex.kill_replica(0, 1)
        assert [f.result(timeout=20) for f in futs] == list(range(30))
        assert ex.health_snapshot()["live_replicas"] == [2]


def test_stage_loss_fails_fast_and_fires_callback_once():
    """k=1 stage death: in-flight + later requests resolve with StageLost
    (the stream never stalls), and on_stage_lost fires exactly once."""
    fired = []

    def boom(x):
        raise ReplicaFailure("device fell over")

    ex = PipelineExecutor([lambda x: x, boom])
    ex.on_stage_lost = fired.append
    with ex:
        futs = [ex.submit(i) for i in range(6)]
        for f in futs:
            with pytest.raises(StageLost) as ei:
                f.result(timeout=10)
            assert ei.value.stage == 1
        # stream is still accepting; new work fails fast, no hang
        with pytest.raises(StageLost):
            ex.submit(99).result(timeout=10)
    assert fired == [1]


def test_kill_stage_loses_all_replicas():
    with PipelineExecutor([lambda x: x], replicas=[2]) as ex:
        ex.kill_stage(0)
        with pytest.raises(StageLost):
            ex.submit(1).result(timeout=10)
        assert ex.health_snapshot()["live_replicas"] == [0]


# ---------------------------------------------------------------------------
# hedged dispatch
# ---------------------------------------------------------------------------
def _straggler_fns(base=0.002, every=5, factor=40.0):
    """First attempt of every ``every``-th item sleeps ``factor``x; any
    re-attempt runs at base speed (a transiently throttled device)."""
    seen = {}
    lock = threading.Lock()

    def fn(x):
        with lock:
            attempt = seen.get(x, 0)
            seen[x] = attempt + 1
        slow = x % every == every - 1 and attempt == 0
        time.sleep(base * (factor if slow else 1.0))
        return x * 3

    return [fn]


def test_hedging_off_by_default_and_bit_identical_when_on():
    inputs = list(range(20))
    with PipelineExecutor(_straggler_fns(), replicas=[3]) as ex:
        plain = [ex.submit(i).result(timeout=30) for i in inputs]
        assert sum(ex.health_snapshot()["hedges"]) == 0   # default: off

    with PipelineExecutor(_straggler_fns(), replicas=[3],
                          hedge_after=0.01) as ex:
        futs = [ex.submit(i) for i in inputs]
        hedged = [f.result(timeout=30) for f in futs]
        h = ex.health_snapshot()
    assert hedged == plain                # bit-identical, same order
    assert sum(h["hedges"]) >= 1          # stragglers were hedged


def test_hedge_duplicates_complete_exactly_once():
    """The merge's dedup-by-sequence makes duplicate results invisible:
    every future resolves once, outputs match submission order."""
    exits = []
    lock = threading.Lock()

    def tap(x):
        with lock:
            exits.append(x)
        return x

    fns = _straggler_fns(every=3) + [tap]
    with PipelineExecutor(fns, replicas=[3, 1], hedge_after=0.01) as ex:
        futs = [ex.submit(i) for i in range(18)]
        assert [f.result(timeout=30) for f in futs] == \
            [i * 3 for i in range(18)]
    assert exits == [i * 3 for i in range(18)]    # once each, in order


# ---------------------------------------------------------------------------
# chaos harness
# ---------------------------------------------------------------------------
def test_kill_schedule_deterministic_and_constrained():
    a = replica_kill_schedule([2, 3, 3], 4, 1.0, seed=11)
    b = replica_kill_schedule([2, 3, 3], 4, 1.0, seed=11)
    assert a == b and len(a) == 4
    assert all(ev.slot != 0 for ev in a)          # spare_last
    capped = replica_kill_schedule([2, 3, 3], 9, 1.0, seed=11,
                                   max_per_stage=1)
    stages = [ev.stage for ev in capped]
    assert len(stages) == len(set(stages))
    full = replica_kill_schedule([2], 2, 1.0, seed=0, spare_last=False)
    assert {ev.slot for ev in full} == {0, 1}     # stage loss allowed


def test_chaos_run_exactly_once_under_kills():
    def work(x):
        time.sleep(0.001)
        return x

    reps = [3, 3]
    events = replica_kill_schedule(reps, 2, 0.08, seed=4, spare_last=True)
    rep = run_chaos_executor([work, work], reps, n_requests=80,
                             interval_s=0.001, events=events)
    assert rep.kills_applied == 2
    assert rep.lost == 0 and rep.misordered == 0 and rep.failed == 0
    assert rep.completed == rep.submitted == 80


def test_chaos_monkey_tracks_hot_swapped_executor():
    """The monkey resolves its target through a getter at fire time, so a
    reconfigure between events retargets the live executor."""
    ex1 = PipelineExecutor([lambda x: x], replicas=[2]).start()
    ex2 = PipelineExecutor([lambda x: x], replicas=[2]).start()
    current = {"ex": ex1}
    monkey = ChaosMonkey(lambda: current["ex"], [
        ChaosEvent(at_s=0.0, kind="kill_replica", stage=0, slot=1),
        ChaosEvent(at_s=0.05, kind="kill_replica", stage=0, slot=1),
    ]).start()
    time.sleep(0.02)
    current["ex"] = ex2
    deadline = time.monotonic() + 5
    while len(monkey.applied) < 2 and time.monotonic() < deadline:
        time.sleep(0.005)
    monkey.join(timeout=5)                 # join after the schedule ran
    assert [ok for _, ok in monkey.applied] == [True, True]
    assert ex1.health_snapshot()["live_replicas"] == [1]
    assert ex2.health_snapshot()["live_replicas"] == [1]
    ex1.stop()
    ex2.stop()


# ---------------------------------------------------------------------------
# degraded-mode replanning (HealthMonitor -> ElasticPlanner -> reconfigure)
# ---------------------------------------------------------------------------
def _builder(delta=1, sleep_s=0.0):
    def build(p):
        def fn(x):
            if sleep_s:
                time.sleep(sleep_s)
            return x + delta
        return [fn] * p.n_stages
    return build


def test_stage_loss_triggers_automatic_replan_zero_lost():
    g = synthetic_cnn(600).to_layer_graph()
    ep = ElasticPlanner(g, "balanced_norefine")
    pl = ep.plan_for(3)
    # ~1 ms per stage: the kill below lands while the first wave is still
    # in flight, so some requests must cross the dead stage and retry
    build = _builder(sleep_s=0.001)
    srv = PipelinedModelServer(pl, build(pl), max_batch=8,
                               max_wait_s=0.002, stage_loss_retries=8)
    srv.executor.start()
    srv.start()
    restores = []
    mon = HealthMonitor(srv, ep, build,
                        policy=FaultPolicy(poll_interval_s=0.005),
                        warm_restore=lambda: restores.append(1)).start()
    try:
        reqs = [srv.submit(i) for i in range(30)]
        time.sleep(0.005)
        srv.executor.kill_stage(1)            # last replicas of stage 1
        reqs += [srv.submit(i) for i in range(30, 60)]
        assert all(r.event.wait(30) for r in reqs)      # zero lost
        assert not [r for r in reqs if r.error is not None]
        # served by the 3-stage plan (+3) or, post-replan, the 2-stage
        # plan (+2) — never anything else
        assert {r.result - r.payload for r in reqs} <= {2, 3}
        assert len(mon.replans) == 1
        assert mon.replans[0]["lost_stages"] == [1]
        assert mon.replans[0]["n_stages"] == 2
        assert srv.plan.n_stages == 2
        assert restores == [1]                # warm restore ran first
        assert srv.snapshot()["retried"] >= 1
    finally:
        mon.stop()
        srv.stop()


def test_health_monitor_withdraws_sick_replica_then_replans():
    """Persistent item failures cross max_consecutive_failures: the probe
    withdraws replicas; when the whole stage is sick, withdrawal becomes
    stage loss and the degraded replan serves the retries."""
    g = synthetic_cnn(600).to_layer_graph()
    ep = ElasticPlanner(g, "balanced_norefine")
    pl = ep.plan_for(2)
    epoch = {"n": 0}

    def build(p):
        e = epoch["n"]
        epoch["n"] += 1
        if e == 0:
            def sick(x):
                raise ValueError("persistent device error")
            return [lambda x: x, sick][:p.n_stages] \
                + [lambda x: x] * max(0, p.n_stages - 2)
        return [lambda x: x] * p.n_stages

    srv = PipelinedModelServer(pl, build(pl), max_batch=4,
                               max_wait_s=0.002, stage_loss_retries=8)
    srv.executor.start()
    srv.start()
    mon = HealthMonitor(
        srv, ep, build,
        policy=FaultPolicy(max_consecutive_failures=3,
                           poll_interval_s=0.005)).start()
    try:
        reqs = [srv.submit(i) for i in range(40)]
        deadline = time.monotonic() + 30
        while not mon.replans and time.monotonic() < deadline:
            time.sleep(0.01)
        assert mon.replans, "sick stage never triggered a replan"
        assert any(reason == "sick" for *_, reason in mon.kills)
        done = [r for r in reqs if r.event.wait(30)]
        assert len(done) == 40
        # casualties of the sick epoch fail with the item error; retries
        # admitted after the swap succeed — nothing hangs, nothing lost
        for r in reqs:
            assert r.error is None or isinstance(r.error, ValueError)
    finally:
        mon.stop()
        srv.stop()


def test_health_monitor_heartbeat_kills_hung_replica():
    """A replica stuck inside the stage fn goes heartbeat-stale while work
    is in flight; the probe withdraws it and the in-flight envelope is
    re-dispatched to a live replica (re-attempt runs fast)."""
    g = synthetic_cnn(600).to_layer_graph()
    ep = ElasticPlanner(g, "balanced_norefine")
    pl = ep.plan_for(1)
    attempts = {}
    lock = threading.Lock()

    def hang_once(x):
        with lock:
            n = attempts.get(x, 0)
            attempts[x] = n + 1
        if x == 3 and n == 0:
            time.sleep(0.6)               # "hung" first attempt
        return x

    class Plan2:                           # 1 logical stage, 2 replicas
        pass

    srv = PipelinedModelServer(pl, [hang_once], max_batch=4,
                               max_wait_s=0.002)
    # replicate by hand: swap in an executor with 2 replicas of the fn
    srv.executor.stop()
    srv.executor = PipelineExecutor([hang_once], replicas=[2],
                                    name="hung-test")
    srv.executor.on_stage_lost = srv._notify_stage_lost
    srv.executor.start()
    srv.start()
    mon = HealthMonitor(
        srv, ep, _builder(0),
        policy=FaultPolicy(heartbeat_timeout_s=0.1,
                           poll_interval_s=0.02)).start()
    try:
        reqs = [srv.submit(i) for i in range(8)]
        assert all(r.event.wait(30) for r in reqs)
        assert not [r for r in reqs if r.error is not None]
        assert [r.result for r in reqs] == list(range(8))
        assert any(reason == "stale" for *_, reason in mon.kills)
    finally:
        mon.stop()
        srv.stop()


# ---------------------------------------------------------------------------
# reconfigure() under concurrent submitters (satellite)
# ---------------------------------------------------------------------------
def test_reconfigure_under_concurrent_submitters():
    """In-flight requests drain to the old plan, queued requests are
    served by the new plan, snapshot() counters stay consistent, and
    nothing is lost or failed across the swap."""
    g = synthetic_cnn(600).to_layer_graph()
    pl3 = plan(g, 3, "balanced_norefine")
    pl2 = plan(g, 2, "balanced_norefine")

    def old_fn(x):
        time.sleep(0.001)
        return ("old", x)

    def new_fn(x):
        return ("new", x)

    srv = PipelinedModelServer(pl3, [old_fn, lambda x: x, lambda x: x],
                               max_batch=8, max_wait_s=0.002)
    srv.executor.start()
    srv.start()
    srv.snapshot()                         # rebase the delta window
    n_threads, n_each = 4, 25
    results = [None] * n_threads

    def submitter(t):
        out = []
        for i in range(n_each):
            out.append(srv.submit((t, i)))
            time.sleep(0.0005)
        results[t] = out

    threads = [threading.Thread(target=submitter, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    time.sleep(0.01)
    srv.reconfigure(pl2, [new_fn, lambda x: x])
    for th in threads:
        th.join()
    reqs = [r for out in results for r in out]
    assert all(r.event.wait(30) for r in reqs)
    assert not [r for r in reqs if r.error is not None]
    tags = {r.result[0] for r in reqs}
    assert tags <= {"old", "new"}
    assert "new" in tags                   # the swap happened under load
    # every request kept its own payload through whichever plan served it
    for r in reqs:
        assert r.result[1] == r.payload
    snap = srv.snapshot()
    assert snap["requests"] == n_threads * n_each
    assert snap["failed"] == 0
    # a post-swap wave is served exclusively by the new plan
    wave = [srv.submit(("w", i)) for i in range(10)]
    assert all(r.event.wait(10) for r in wave)
    assert {r.result[0] for r in wave} == {"new"}
    srv.stop()


# ---------------------------------------------------------------------------
# runtime satellites
# ---------------------------------------------------------------------------
def test_speculative_executor_prefers_first_success():
    """A fast-failing primary must not win over a later-succeeding
    backup (the old FIRST_COMPLETED bug)."""
    calls = {"n": 0}
    lock = threading.Lock()

    def flaky(x):
        with lock:
            calls["n"] += 1
            first = calls["n"] == 1
        if first:
            raise RuntimeError("transient")
        return x + 1

    se = SpeculativeExecutor(flaky, hedge_after=0.05)
    assert se.submit(1) == 2
    assert se.hedged == 1
    se.shutdown()                          # joins the pool (wait=True)


def test_speculative_executor_raises_when_all_attempts_fail():
    def always(x):
        raise ValueError("both died")

    se = SpeculativeExecutor(always, hedge_after=0.005)
    with pytest.raises(ValueError, match="both died"):
        se.submit(0)
    se.shutdown(wait=False)


def test_supervisor_restarts_clean_on_empty_store_any_exception():
    """No checkpoint yet + a non-RuntimeError failure: restart from
    start_step with the *initial* state (the old code called restore()
    on an empty store and only caught RuntimeError)."""
    store = CheckpointStore(tempfile.mkdtemp(), keep=2)
    assert not store.has_checkpoint()
    failed = []

    def step_fn(state, step):
        if step == 2 and not failed:
            failed.append(step)
            raise OSError("device fell off the bus")
        return state + 1, {}

    sup = TrainSupervisor(store, step_fn, ckpt_every=100, async_ckpt=False)
    state, rep = sup.run(0, 5)
    assert rep.restarts == 1 and rep.final_step == 5
    assert state == 5                      # replayed from scratch exactly
    assert store.has_checkpoint()          # final checkpoint landed


def test_failure_injector_rate_independent_of_deterministic():
    """A deterministic firing at step k no longer suppresses the seeded
    random decision at the same step (separate fired sets), and the rate
    coin is flipped exactly once per (target, step)."""
    inj = FailureInjector(fail_at_steps=[3], fail_rate=1.0, seed=0)
    with pytest.raises(RuntimeError, match="at step 3"):
        inj.check(3)                       # deterministic fires first
    with pytest.raises(RuntimeError, match="random failure at step 3"):
        inj.check(3)                       # rate=1.0 still fires after
    inj.check(3)                           # both decided: clean from now

    targeted = FailureInjector(fail_at_steps=[0], fail_target="s1")
    targeted.check(0, target="s0")         # filtered: wrong target
    with pytest.raises(RuntimeError):
        targeted.check(0, target="s1")


def test_failure_injector_wrap_counts_calls_per_target():
    inj = FailureInjector(fail_at_steps=[1], exc_type=ReplicaFailure)
    fa = inj.wrap(lambda x: x * 2, "a")
    fb = inj.wrap(lambda x: x * 3, "b")
    assert fa(1) == 2 and fb(1) == 3       # call #0 per target
    with pytest.raises(ReplicaFailure):
        fa(1)                              # a's call #1
    with pytest.raises(ReplicaFailure):
        fb(1)                              # b's own call #1: independent
    assert fa(4) == 8 and fb(4) == 12
