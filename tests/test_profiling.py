"""Profiling / CostSource tests (ISSUE 5).

* ProfileTrace JSON round-trip: versioned schema, unknown-field tolerance
  (trace and sample level), wrong-format rejection.
* Layer-granular profiler: depth coverage, positive times, static columns
  matching the graph, determinism of the static fields.
* AnalyticCostSource plan equivalence over all 21 Table-1 models — plans
  through an explicit analytic source are bit-identical to the default
  path AND to the naive (use_engine=False) model, the pre-CostSource
  ground truth (acceptance criterion).
* TraceCostSource: measured per-depth times drive the engine (prefix-sum
  additivity), analytic fallback for unprofiled depths, device scaling.
* CalibratedCostSource determinism: same trace -> same coefficients ->
  same materialized times and plans; degenerate traces fall back to the
  analytic prediction.
* PlanReport provenance: cost_source recorded, trace stage times +
  modeled-vs-trace error present iff a trace covers the plan.
"""
import dataclasses
import json

import pytest

from conftest import api_plan
from repro.api import DeploymentSpec, plan
from repro.core import EdgeTPUModel, EdgeTPUSpec, chain_graph
from repro.core.cost_engine import SegmentCostEngine
from repro.core.segmentation import segment_ranges
from repro.models.cnn import REAL_CNNS, synthetic_cnn
from repro.profiling import (AnalyticCostSource, CalibratedCostSource,
                             DepthSample, ProfileTrace, TraceCostSource,
                             fit_trace, parse_cost_source,
                             resolve_cost_source, trimmed_mean)


def toy_graph(n=8, params=50_000, macs=5_000_000, out_bytes=1024):
    return chain_graph("toy", [(f"l{i}", params, macs, out_bytes)
                               for i in range(n)])


def toy_trace(g, base=1e-3, step=1e-4, skip=()):
    """A synthetic trace over `g` with deterministic per-depth times."""
    P, M, B = (g.params_per_depth(), g.macs_per_depth(),
               g.bytes_per_depth())
    samples = tuple(
        DepthSample(depth=d, time_s=base + d * step,
                    layers=tuple(g.levels()[d]), params=P[d], macs=M[d],
                    weight_bytes=B[d], raw_times_s=(base + d * step,))
        for d in range(g.depth) if d not in skip)
    return ProfileTrace(graph_name=g.name, samples=samples,
                        device="synthetic", warmup=1, repeats=1)


# ---------------------------------------------------------------------------
# trace schema
# ---------------------------------------------------------------------------
def test_trace_json_roundtrip_exact():
    tr = toy_trace(toy_graph())
    back = ProfileTrace.from_json(tr.to_json())
    assert back == tr
    json.loads(tr.to_json())               # plain JSON, no repr smuggling


def test_trace_unknown_fields_tolerated():
    """A newer profiler may add columns; an older planner must still read
    the times (both at the trace level and the per-sample level)."""
    tr = toy_trace(toy_graph(4))
    doc = tr.to_dict()
    doc["compiler_version"] = "edgetpu-2.99"        # unknown trace field
    doc["samples"][0]["power_mw"] = 1234            # unknown sample field
    back = ProfileTrace.from_dict(doc)
    assert back.depth_time_map() == tr.depth_time_map()
    assert back.samples[0].layers == tr.samples[0].layers


def test_trace_minor_version_accepted_wrong_format_rejected():
    tr = toy_trace(toy_graph(4))
    doc = tr.to_dict()
    doc["format"] = "repro.profile_trace/v1.1"      # minor bump: readable
    ProfileTrace.from_dict(doc)
    doc["format"] = "repro.profile_trace/v2"
    with pytest.raises(ValueError, match="profile trace"):
        ProfileTrace.from_dict(doc)
    with pytest.raises(ValueError, match="profile trace"):
        ProfileTrace.from_dict({"graph_name": "x", "samples": []})


def test_trace_save_load_and_queries(tmp_path):
    g = toy_graph(6)
    tr = toy_trace(g, skip=(3,))
    path = tr.save(str(tmp_path / "trace.json"))
    back = ProfileTrace.load(path)
    assert back == tr
    assert back.coverage(g.depth) == pytest.approx(5 / 6)
    assert back.stage_times([(0, 2)]) == pytest.approx(
        [sum(back.depth_time_map()[d] for d in range(3))])
    assert back.stage_times([(2, 4)]) is None       # touches unprofiled d=3


def test_trimmed_mean():
    assert trimmed_mean([1.0]) == 1.0
    assert trimmed_mean([100.0, 1.0, 2.0, 3.0, 0.0]) == 2.0   # trims ends
    with pytest.raises(ValueError):
        trimmed_mean([])


# ---------------------------------------------------------------------------
# profiler (real JAX forwards on a tiny model)
# ---------------------------------------------------------------------------
def test_profiler_captures_every_depth():
    from repro.profiling import profile_model
    m = synthetic_cnn(8, L=3, hw=16)
    g = m.to_layer_graph()
    tr = profile_model(m, warmup=1, repeats=2, stamp_time=False)
    assert tr.graph_name == g.name
    assert tr.depths == tuple(range(g.depth))
    assert all(s.time_s > 0 for s in tr.samples)
    assert all(len(s.raw_times_s) == 2 for s in tr.samples)
    # static columns are the graph's own accounting
    assert [s.params for s in tr.samples] == g.params_per_depth()
    assert [s.macs for s in tr.samples] == g.macs_per_depth()
    assert [s.weight_bytes for s in tr.samples] == g.bytes_per_depth()
    assert tr.captured_unix_s == 0.0


# ---------------------------------------------------------------------------
# analytic-source equivalence (acceptance criterion: all 21 models)
# ---------------------------------------------------------------------------
ALL_STRATEGIES = ("comp", "balanced", "balanced_norefine", "balanced_cost",
                  "opt")


@pytest.mark.parametrize("name", sorted(REAL_CNNS))
def test_analytic_source_plans_bit_identical_all_models(name):
    """For every Table-1 model and homogeneous strategy (prof at s=2 —
    its C(d-1, s-1) search is the paper's infeasibility point), planning
    through an explicit AnalyticCostSource equals the default engine path
    AND the naive walk-every-layer model — the pre-CostSource ground
    truth: same cuts, same modeled stage times, same refinement."""
    g = REAL_CNNS[name]().to_layer_graph()
    naive = EdgeTPUModel(g, use_engine=False)
    src_model = EdgeTPUModel(g, cost_source=AnalyticCostSource())
    s = max(2, min(4, g.depth - 1))
    matrix = [(strat, s) for strat in ALL_STRATEGIES] + [("prof", 2)]
    for strat, n in matrix:
        spec = DeploymentSpec(stages=n, strategy=strat)
        default = plan(spec, graph=g)
        via_src = plan(spec, graph=g, tpu_model=src_model)
        via_naive = plan(spec, graph=g, tpu_model=naive)
        assert via_src.cuts == default.cuts == via_naive.cuts, (name, strat)
        assert via_src.stage_times_s == default.stage_times_s \
            == via_naive.stage_times_s, (name, strat)
        assert (via_src.refinement is None) == (default.refinement is None)
        if via_src.refinement is not None:
            assert via_src.refinement.cuts == default.refinement.cuts


def test_explicit_analytic_cost_source_spec_is_default():
    g = REAL_CNNS["ResNet50"]().to_layer_graph()
    a = plan(DeploymentSpec(stages=4, strategy="balanced"), graph=g)
    b = plan(DeploymentSpec(stages=4, strategy="balanced",
                            cost_source="analytic"), graph=g)
    assert a.cuts == b.cuts and a.stage_times_s == b.stage_times_s
    assert b.report.cost_source == "analytic"
    assert not b.report.has_trace


# ---------------------------------------------------------------------------
# trace-backed sources
# ---------------------------------------------------------------------------
def test_trace_source_times_are_prefix_additive():
    g = toy_graph(8)
    tr = toy_trace(g)
    eng = SegmentCostEngine(g, EdgeTPUSpec(), TraceCostSource(tr))
    assert eng.is_measured
    tmap = tr.depth_time_map()
    for lo, hi in ((0, 0), (0, 3), (2, 7), (5, 6)):
        expect = sum(tmap[d] for d in range(lo, hi + 1))
        assert eng.segment_compute_time(lo, hi) == pytest.approx(expect)
        # full segment time adds the memory-model transfer terms on top
        assert eng.segment_time(lo, hi) >= expect


def test_trace_source_unprofiled_depth_falls_back_to_analytic():
    g = toy_graph(8)
    spec = EdgeTPUSpec()
    tr = toy_trace(g, skip=(5,))
    eng = SegmentCostEngine(g, spec, TraceCostSource(tr))
    analytic = (g.macs_per_depth()[5] / spec.macs_per_s
                + g.bytes_per_depth()[5] / (spec.weight_load_gbps * 1e9))
    assert eng.segment_compute_time(5, 5) == pytest.approx(analytic)
    # profiled neighbours still use the measured numbers
    assert eng.segment_compute_time(4, 4) == pytest.approx(
        tr.depth_time_map()[4])


def test_trace_source_scales_with_device_compute():
    """with_spec on a 2x-compute device halves measured times (the same
    way it doubles the analytic rate); the reference device applies no
    float op at all."""
    from repro.core import DeviceSpec
    g = toy_graph(6)
    base = EdgeTPUSpec()
    tr = toy_trace(g)
    eng = SegmentCostEngine(g, base, TraceCostSource(tr))
    t_ref = eng.segment_compute_time(0, 5)
    fast = DeviceSpec(name="fast", compute_scale=2.0).specialize(base)
    eng2 = eng.with_spec(fast)
    assert eng2.segment_compute_time(0, 5) == pytest.approx(t_ref / 2)
    assert eng.segment_compute_time(0, 5) == t_ref      # original untouched


def test_trace_backed_plan_balances_measured_time():
    """A graph with uniform params but a heavily skewed measured profile:
    the params-balanced split ignores the skew, the trace-backed
    balanced_cost split shifts cuts toward the slow depths."""
    g = toy_graph(10)
    times = [1e-3] * 10
    times[0] = times[1] = 20e-3                 # slow front
    samples = tuple(DepthSample(depth=d, time_s=times[d],
                                macs=g.macs_per_depth()[d],
                                weight_bytes=g.bytes_per_depth()[d])
                    for d in range(10))
    tr = ProfileTrace(graph_name=g.name, samples=samples)
    src_model = EdgeTPUModel(g, cost_source=TraceCostSource(tr))
    traced = plan(DeploymentSpec(stages=2, strategy="balanced_cost",
                                 refine=False), graph=g,
                  tpu_model=src_model)
    uniform = plan(DeploymentSpec(stages=2, strategy="balanced_norefine"),
                   graph=g)
    assert uniform.cuts == [4]                  # params see no skew
    assert traced.cuts[0] < 4                   # measured time does


def test_resolve_cost_source_and_parse(tmp_path):
    assert parse_cost_source("analytic") == ("analytic", None)
    assert parse_cost_source("trace:a/b.json") == ("trace", "a/b.json")
    assert parse_cost_source("calibrated:c.json") == ("calibrated", "c.json")
    for bad in ("vibes", "trace:", "analytic:x"):
        with pytest.raises(ValueError):
            parse_cost_source(bad)
    g = toy_graph(6)
    path = str(tmp_path / "t.json")
    toy_trace(g).save(path)
    assert isinstance(resolve_cost_source("analytic"), AnalyticCostSource)
    assert isinstance(resolve_cost_source(f"trace:{path}"), TraceCostSource)
    assert isinstance(resolve_cost_source(f"calibrated:{path}"),
                      CalibratedCostSource)


def test_spec_cost_source_end_to_end(tmp_path):
    """cost_source='trace:<path>' through the whole front door: the plan
    is priced from the artifact and the report records provenance +
    modeled-vs-trace error."""
    g = toy_graph(8)
    path = str(tmp_path / "t.json")
    toy_trace(g).save(path)
    ref = f"trace:{path}"
    pl = plan(DeploymentSpec(stages=2, strategy="opt", cost_source=ref),
              graph=g)
    rep = pl.report
    assert rep.cost_source == ref
    assert rep.has_trace
    assert len(rep.trace_stage_times_s) == 2
    assert rep.stage_time_error_pct >= 0.0
    assert "vs trace" in rep.describe()
    # round-trips with the plan document
    from repro.core import PlacementPlan
    back = PlacementPlan.from_json(pl.to_json())
    assert back.report == rep


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------
def _linear_trace(g, mac_s=2e-12, load_s=1e-9, fixed=5e-5):
    samples = tuple(DepthSample(
        depth=d, time_s=(g.macs_per_depth()[d] * mac_s
                         + g.bytes_per_depth()[d] * load_s + fixed),
        macs=g.macs_per_depth()[d], weight_bytes=g.bytes_per_depth()[d])
        for d in range(g.depth))
    return ProfileTrace(graph_name=g.name, samples=samples)


def test_fit_recovers_planted_coefficients():
    g = chain_graph("mix", [(f"l{i}", p, m, 64)
                            for i, (p, m) in enumerate(
                                [(10_000, 9e6), (80_000, 2e6), (5_000, 7e6),
                                 (120_000, 1e6), (40_000, 4e6)])])
    fit = fit_trace(_linear_trace(g))
    assert fit.mac_s == pytest.approx(2e-12, rel=1e-6)
    assert fit.load_s_per_byte == pytest.approx(1e-9, rel=1e-6)
    assert fit.fixed_s == pytest.approx(5e-5, rel=1e-6)
    assert fit.residual_rms_s < 1e-12


def test_calibrated_source_is_deterministic():
    """Same trace -> same coefficients -> same materialized times (the
    acceptance-listed determinism property)."""
    g = toy_graph(8, params=30_000, macs=8_000_000)
    tr = toy_trace(g, base=2e-3, step=3e-4)
    s1, s2 = CalibratedCostSource(tr), CalibratedCostSource(tr)
    assert s1.coefficients() == s2.coefficients()
    spec = EdgeTPUSpec()
    e1 = SegmentCostEngine(g, spec, s1)
    e2 = SegmentCostEngine(g, spec, s2)
    for lo, hi in ((0, 7), (0, 3), (4, 7), (2, 2)):
        assert e1.segment_time(lo, hi) == e2.segment_time(lo, hi)
    p1 = api_plan(g, 3, "balanced_cost",
                  tpu_model=EdgeTPUModel(g, cost_source=s1))
    p2 = api_plan(g, 3, "balanced_cost",
                  tpu_model=EdgeTPUModel(g, cost_source=s2))
    assert p1.cuts == p2.cuts and p1.stage_times_s == p2.stage_times_s


def test_calibrated_prediction_applies_the_cliff_coefficient():
    """A trace whose per-depth times jump once cumulative weights cross
    the on-chip cliff: the fit captures the jump in cliff_s_per_byte and
    the source's predictions must apply it — post-cliff depths predict
    far above the pre-cliff plateau (regression: the coefficient used to
    be fit but dropped at prediction time)."""
    MIB = 1024 * 1024
    per_depth = 2 * MIB                      # 8 depths x 2 MiB: cliff ~d4
    g = chain_graph("cliffy", [(f"l{i}", per_depth, 1_000, 64)
                               for i in range(8)])
    ref = EdgeTPUSpec()
    capacity = ref.onchip_bytes - ref.fixed_reserve
    from repro.profiling import cliff_bytes_per_depth
    cliffs = cliff_bytes_per_depth(tuple(g.bytes_per_depth()), capacity)
    samples = tuple(DepthSample(
        depth=d, time_s=1e-3 + 5e-9 * cliffs[d],   # post-cliff: ~10x slower
        macs=g.macs_per_depth()[d], weight_bytes=g.bytes_per_depth()[d])
        for d in range(8))
    src = CalibratedCostSource(ProfileTrace(graph_name=g.name,
                                            samples=samples))
    assert src.fit is not None and src.fit.cliff_s_per_byte > 0
    eng = SegmentCostEngine(g, ref, src)
    pre = eng.segment_compute_time(0, 0)
    post = eng.segment_compute_time(7, 7)
    assert post == pytest.approx(samples[7].time_s, rel=1e-3)
    assert post > 3 * pre


def test_cost_source_point_queries():
    """The protocol's per-depth point queries answer from one cached
    materialization (trace-backed and analytic alike)."""
    g = toy_graph(6)
    spec = EdgeTPUSpec()
    tr = toy_trace(g)
    src = TraceCostSource(tr)
    for d in (0, 3, 5):
        assert src.layer_time_s(d, g, spec) == tr.depth_time_map()[d]
        assert src.layer_params(d, g) == g.params_per_depth()[d]
        assert src.layer_weight_bytes(d, g) == g.bytes_per_depth()[d]
        assert src.activation_bytes(d, g) == g.out_bytes_per_depth()[d]
    ana = AnalyticCostSource()
    assert ana.layer_time_s(2, g, spec) == pytest.approx(
        g.macs_per_depth()[2] / spec.macs_per_s
        + g.bytes_per_depth()[2] / (spec.weight_load_gbps * 1e9))


def test_naive_model_reporter_does_not_build_engine():
    """GraphReporter over the use_engine=False baseline must not silently
    construct the fast engine (it is the before/after benchmark's naive
    side)."""
    from repro.core import GraphReporter
    g = toy_graph(6)
    naive = EdgeTPUModel(g, use_engine=False)
    rep = GraphReporter(naive)
    assert naive._engine is None
    assert [rep.depth_bytes(d) for d in range(g.depth)] \
        == g.bytes_per_depth()


def test_calibrated_source_degenerate_trace_falls_back():
    g = toy_graph(6)
    one = ProfileTrace(graph_name=g.name, samples=(
        DepthSample(depth=0, time_s=1e-3, macs=g.macs_per_depth()[0],
                    weight_bytes=g.bytes_per_depth()[0]),))
    src = CalibratedCostSource(one)
    assert src.fit is None and src.coefficients() == {}
    spec = EdgeTPUSpec()
    eng = SegmentCostEngine(g, spec, src)
    plain = SegmentCostEngine(g, spec)
    for lo, hi in ((0, 5), (1, 3)):
        assert eng.segment_time(lo, hi) == pytest.approx(
            plain.segment_time(lo, hi))


def test_calibrated_tracks_trace_better_than_analytic():
    """The point of calibration: on a trace whose magnitudes the analytic
    Edge TPU model mispredicts, the calibrated source's stage-time error
    is smaller (the BENCH_profile acceptance, in miniature)."""
    from repro.api import PlanReport
    g = toy_graph(10, params=40_000, macs=20_000_000)
    tr = toy_trace(g, base=3e-3, step=2e-4)       # ms-scale: CPU-like
    pl = plan(DeploymentSpec(stages=3, strategy="balanced_norefine"),
              graph=g)
    analytic_rep = PlanReport.from_plan(
        pl, base_model=EdgeTPUModel(g), trace=tr)
    cal_model = EdgeTPUModel(g, cost_source=CalibratedCostSource(tr))
    pl_c = plan(DeploymentSpec(stages=3, strategy="balanced_norefine"),
                graph=g, tpu_model=cal_model)
    cal_rep = PlanReport.from_plan(pl_c, base_model=cal_model, trace=tr)
    assert analytic_rep.has_trace and cal_rep.has_trace
    assert cal_rep.stage_time_error_pct < analytic_rep.stage_time_error_pct


# ---------------------------------------------------------------------------
# shared bytes accounting (satellite)
# ---------------------------------------------------------------------------
def test_refiner_bytes_come_from_the_engine():
    """GraphReporter's multi-step move sizing reads the engine's per-depth
    bytes — one accounting for planner and refiner."""
    from repro.core import GraphReporter
    g = toy_graph(6)
    m = EdgeTPUModel(g)
    rep = GraphReporter(m)
    assert [rep.depth_bytes(d) for d in range(g.depth)] \
        == m.engine.depth_weight_bytes() == g.bytes_per_depth()


def test_memory_model_identical_across_paths():
    """Naive EdgeTPUModel, engine, and the shared costs helpers agree on
    capacity and greedy split for every segment of a real model."""
    from repro.core.costs import greedy_layer_split, weight_capacity_bytes
    g = REAL_CNNS["MobileNetV2"]().to_layer_graph()
    fast = EdgeTPUModel(g)
    naive = EdgeTPUModel(g, use_engine=False)
    spec = fast.spec
    for lo, hi in ((0, g.depth - 1), (3, 17), (10, 10)):
        nr = naive.segment_memory(lo, hi)
        assert fast.engine.segment_split(lo, hi) \
            == (nr.device_bytes, nr.host_bytes)
        cap = weight_capacity_bytes(
            spec.onchip_bytes, spec.fixed_reserve, spec.act_reserve_factor,
            fast.engine.segment_max_activation(lo, hi))
        layers = [n for lvl in g.levels()[lo:hi + 1] for n in lvl]
        assert greedy_layer_split([g.nodes[n].bytes for n in layers], cap) \
            == (nr.device_bytes, nr.host_bytes)
