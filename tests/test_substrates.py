"""Substrate tests: optimizer, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.data import DataConfig, SyntheticLMDataset, prefetch
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, cosine_warmup_schedule)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, min_lr_ratio=1.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = adamw_init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(cosine_warmup_schedule(cfg, jnp.array(5))) == pytest.approx(0.5)
    assert float(cosine_warmup_schedule(cfg, jnp.array(10))) == pytest.approx(1.0)
    end = float(cosine_warmup_schedule(cfg, jnp.array(100)))
    assert end == pytest.approx(0.1, rel=1e-3)


def test_grad_clip():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_adamw_bf16_params_fp32_moments():
    cfg = AdamWConfig()
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state["mu"]["w"].dtype == jnp.float32
    p2, s2, _ = adamw_update(cfg, params, {"w": jnp.ones((4,), jnp.bfloat16)},
                             state)
    assert p2["w"].dtype == jnp.bfloat16


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_data_step_addressable_and_deterministic():
    cfg = DataConfig(seed=3, global_batch=4, seq_len=16, vocab=64)
    ds = SyntheticLMDataset(cfg)
    b1 = ds.batch_at(7)
    b2 = ds.batch_at(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch_at(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    assert b1["tokens"].shape == b1["labels"].shape == (4, 16)


def test_data_host_sharding_partitions_stream():
    full = SyntheticLMDataset(DataConfig(global_batch=8, num_hosts=1))
    h0 = SyntheticLMDataset(DataConfig(global_batch=8, num_hosts=2,
                                       host_id=0))
    assert h0.cfg.host_batch == 4
    assert full.cfg.host_batch == 8


def test_prefetch_preserves_order():
    out = list(prefetch(iter(range(20)), depth=4))
    assert out == list(range(20))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16),
                  "step": jnp.array(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    t = _tree()
    store.save(10, t)
    step, restored = store.restore(jax.tree.map(jnp.zeros_like, t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_retention_and_latest(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        store.save(s, _tree())
    assert store.steps() == [3, 4]
    assert store.latest_step() == 4


def test_checkpoint_async(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    store.save(5, _tree(), blocking=False)
    store.wait()
    assert store.latest_step() == 5


def test_corrupted_checkpoint_falls_back(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=3)
    store.save(1, _tree())
    store.save(2, _tree())
    # corrupt the newest
    d = os.path.join(str(tmp_path), "step_0000000002")
    path = os.path.join(d, "leaf_00000.npy")
    with open(path, "r+b") as f:
        f.seek(60)
        f.write(b"\xff\xff\xff\xff")
    step, _ = store.restore(_tree())
    assert step == 1                      # fell back to the verified one


def test_restore_empty_dir(tmp_path):
    store = CheckpointStore(str(tmp_path))
    step, tree = store.restore(_tree())
    assert step is None
