"""Front-door API tests (ISSUE 4): the declarative
DeploymentSpec -> plan -> Deployment surface.

* Strategy self-consistency: placement delegation to the plain planner,
  refine-override composition, explicit ``cost_source="analytic"``
  bit-identical to the default (the full 21-model AnalyticCostSource
  equivalence matrix lives in tests/test_profiling.py).
* DeploymentSpec / PlanReport JSON round-trip property tests (hypothesis).
* The removed ``repro.core.planner`` entry points raise with a pointer at
  the front door (ISSUE 5: the one-release shims are gone).
* Neutral edge-case records: ``PlanReport`` on 1-stage/empty plans,
  ``latency_percentiles([])``.
* Deployment handle: executor/serve wiring, reconfigure hot-swap,
  from_plan, spec validation errors.
"""
import json
import warnings

import pytest

from repro.api import (DeploymentSpec, Deployment, PlanReport, PlanStrategy,
                       available_strategies, deploy, get_strategy, plan,
                       register_strategy, resolve_model_graph)
from repro.core import (DeviceSpec, EdgeTPUModel, PlacementPlan, Topology,
                        chain_graph)
from repro.core import planner as legacy
from repro.fleet import FleetMemberSpec, FleetSpec
from repro.models.cnn import REAL_CNNS
from repro.serving import latency_percentiles

try:                    # property tests need hypothesis (requirements-dev);
    import hypothesis   # the rest of this file must run without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def toy_graph(n=6, params=50_000, macs=5_000_000, out_bytes=1024):
    return chain_graph("toy", [(f"l{i}", params, macs, out_bytes)
                               for i in range(n)])


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
def test_registry_has_all_legacy_strategies():
    names = available_strategies()
    for s in ("comp", "prof", "balanced", "balanced_norefine",
              "balanced_cost", "opt", "placement", "balanced_placement"):
        assert s in names, s
    # legacy plan.strategy strings resolve through aliases
    assert get_strategy("opt_placement") is get_strategy("placement")


def test_unknown_strategy_raises_with_choices():
    with pytest.raises(ValueError, match="unknown strategy"):
        plan(DeploymentSpec(stages=2, strategy="nope"), graph=toy_graph())


def test_register_strategy_plugs_in():
    @register_strategy("first_half")
    class FirstHalf(PlanStrategy):
        objective = "demo"

        def plan(self, ctx):
            cut = max(0, ctx.graph.depth // 2 - 1)
            return PlacementPlan.from_cuts(ctx.graph, [cut],
                                           strategy=self.name)

    try:
        pl = plan(DeploymentSpec(strategy="first_half"), graph=toy_graph(8))
        assert pl.strategy == "first_half"
        assert pl.n_stages == 2 and pl.cuts == [3]
        assert pl.report is not None          # report attaches to plugins too
    finally:
        from repro.api import strategies as S
        S._REGISTRY.pop("first_half", None)


# ---------------------------------------------------------------------------
# strategy self-consistency (the 21-model AnalyticCostSource equivalence
# matrix — the ISSUE 5 acceptance criterion — lives in tests/test_profiling)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("name", ("ResNet50", "MobileNetV2"))
def test_placement_delegation_matches_plain_planner(name):
    """Homogeneous reference topology with replicate=False delegates to
    the plain 'opt' planner — bit-identical cuts and times."""
    g = REAL_CNNS[name]().to_layer_graph()
    s = max(2, min(3, g.depth - 1))
    placed = plan(DeploymentSpec(strategy="placement", device_budget=s,
                                 replicate=False), graph=g)
    plain = plan(DeploymentSpec(stages=s, strategy="opt"), graph=g)
    assert placed.cuts == plain.cuts
    assert placed.stage_times_s == plain.stage_times_s
    assert placed.replica_counts == [1] * s


@pytest.mark.parametrize("name", ("MobileNet", "MobileNetV2",
                                  "EfficientNetLiteB0"))
def test_placement_joint_dp_ignores_cost_source_threading(name):
    """The joint cuts+replicas DP must price identically through the
    default path and an explicit analytic CostSource."""
    g = REAL_CNNS[name]().to_layer_graph()
    new = plan(DeploymentSpec(strategy="placement", device_budget=4),
               graph=g)
    explicit = plan(DeploymentSpec(strategy="placement", device_budget=4,
                                   cost_source="analytic"), graph=g)
    assert new.cuts == explicit.cuts
    assert new.replica_counts == explicit.replica_counts
    assert new.stage_times_s == explicit.stage_times_s
    assert new.strategy == explicit.strategy == "opt_placement"


def test_balanced_placement_heterogeneous_devices_assigned():
    g = toy_graph(12)
    topo = Topology(devices=(DeviceSpec(name="fast", compute_scale=2.0),
                             DeviceSpec(), DeviceSpec()))
    new = plan(DeploymentSpec(strategy="balanced_placement", topology=topo),
               graph=g)
    explicit = plan(DeploymentSpec(strategy="balanced_placement",
                                   topology=topo, cost_source="analytic"),
                    graph=g)
    assert new.cuts == explicit.cuts
    assert new.stage_times_s == explicit.stage_times_s
    assert [d.name for d in topo.devices[:new.n_stages]] \
        == [s.device.name for s in new.stages]


def test_refine_override_composes():
    """refine=False on 'balanced' == 'balanced_norefine'; refine=True on
    'comp' runs the §6.1.3 post-pass over comp cuts."""
    g = REAL_CNNS["ResNet50"]().to_layer_graph()
    off = plan(DeploymentSpec(stages=4, strategy="balanced", refine=False),
               graph=g)
    nore = plan(DeploymentSpec(stages=4, strategy="balanced_norefine"),
                graph=g)
    assert off.cuts == nore.cuts and off.refinement is None
    comp_ref = plan(DeploymentSpec(stages=4, strategy="comp", refine=True),
                    graph=g)
    assert comp_ref.refinement is not None
    if comp_ref.refinement.converged:
        assert comp_ref.report.spill_bytes == 0


def test_auto_stage_count_matches_min_stages_rule():
    g = REAL_CNNS["ResNet50"]().to_layer_graph()
    m = EdgeTPUModel(g)
    pl = plan(DeploymentSpec(strategy="balanced"), graph=g, tpu_model=m)
    from repro.core.placement import min_stages_no_spill
    assert pl.n_stages == min_stages_no_spill(g, m)


def test_model_ref_resolution():
    direct = REAL_CNNS["MobileNet"]().to_layer_graph()
    via_ref = plan(DeploymentSpec(model="cnn:MobileNet", stages=3,
                                  strategy="comp"))
    assert via_ref.cuts == plan(DeploymentSpec(stages=3, strategy="comp"),
                                graph=direct).cuts
    g = resolve_model_graph("synthetic-cnn:500")
    assert g.depth > 0
    with pytest.raises(ValueError, match="unknown CNN"):
        resolve_model_graph("cnn:NotAModel")
    with pytest.raises(ValueError, match="model ref"):
        resolve_model_graph("weird")
    with pytest.raises(ValueError, match="no model ref"):
        plan(DeploymentSpec(stages=2, strategy="comp"))


def test_report_priced_with_the_planners_model():
    """The report must not contradict the plan: a custom tpu_model that
    spills shows up in report.spill_bytes/capacity, not the default 8 MiB
    device's view."""
    MIB = 2 ** 20
    g = REAL_CNNS["ResNet50"]().to_layer_graph()
    from repro.core import EdgeTPUSpec
    tiny = EdgeTPUModel(g, EdgeTPUSpec(onchip_bytes=2 * MIB))
    pl = plan(DeploymentSpec(stages=4, strategy="balanced_norefine"),
              graph=g, tpu_model=tiny)
    assert pl.report.stage_capacity_bytes == (2 * MIB,) * 4
    expected_spill = sum(m.host_bytes for m in tiny.stage_memories(pl.cuts))
    assert pl.report.spill_bytes == expected_spill > 0


def test_reconfigure_keeps_pricing_overrides():
    """deploy(base_spec=...) resizes must replan under the same device
    constants, not silently fall back to the defaults."""
    MIB = 2 ** 20
    from repro.core import EdgeTPUSpec
    g = REAL_CNNS["ResNet50"]().to_layer_graph()
    custom = EdgeTPUSpec(onchip_bytes=4 * MIB)
    dep = deploy(DeploymentSpec(stages=6, strategy="balanced"), graph=g,
                 base_spec=custom, stage_fn_builder=_stage_fn_builder)
    new_plan = dep.reconfigure(stages=7)
    assert new_plan.report.stage_capacity_bytes == (4 * MIB,) * 7
    direct = plan(DeploymentSpec(stages=7, strategy="balanced"), graph=g,
                  base_spec=custom)
    assert new_plan.cuts == direct.cuts


def test_memory_headroom_tightens_capacity():
    g = REAL_CNNS["ResNet50"]().to_layer_graph()
    base = plan(DeploymentSpec(stages=4, strategy="balanced"), graph=g)
    MIB = 2 ** 20
    tight = plan(DeploymentSpec(stages=4, strategy="balanced",
                                memory_headroom_bytes=2 * MIB), graph=g)
    assert tight.report.stage_capacity_bytes[0] \
        == base.report.stage_capacity_bytes[0] - 2 * MIB


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------
def test_spec_validation_errors():
    with pytest.raises(ValueError, match="mutually exclusive"):
        DeploymentSpec(topology=Topology.homogeneous(2), device_budget=2)
    with pytest.raises(ValueError, match="stages"):
        DeploymentSpec(stages=0)
    with pytest.raises(ValueError, match="strategy"):
        DeploymentSpec(strategy="")
    with pytest.raises(ValueError, match="topology"):
        plan(DeploymentSpec(strategy="placement", stages=2),
             graph=toy_graph())
    with pytest.raises(ValueError, match="objective"):
        plan(DeploymentSpec(stages=2, strategy="opt",
                            objective="balance_params"), graph=toy_graph())
    with pytest.raises(ValueError, match="cost source"):
        DeploymentSpec(stages=2, cost_source="vibes")
    with pytest.raises(ValueError, match="trace path"):
        DeploymentSpec(stages=2, cost_source="trace:")
    with pytest.raises(ValueError, match="no argument"):
        DeploymentSpec(stages=2, cost_source="analytic:x")


def test_spec_objective_accepted_when_matching():
    pl = plan(DeploymentSpec(stages=2, strategy="opt",
                             objective="min_max_stage_time"),
              graph=toy_graph())
    assert pl.n_stages == 2


def test_with_stages_resize_semantics():
    s = DeploymentSpec(stages=4, strategy="balanced")
    assert s.with_stages(3).stages == 3
    b = DeploymentSpec(strategy="placement", device_budget=4)
    assert b.with_stages(3).device_budget == 3
    topo = Topology(devices=(DeviceSpec(name="a"), DeviceSpec(name="b"),
                             DeviceSpec(name="c")))
    t = DeploymentSpec(strategy="placement", topology=topo)
    shrunk = t.with_stages(2)
    assert [d.name for d in shrunk.topology.devices] == ["a", "b"]


# ---------------------------------------------------------------------------
# JSON round-trips (hypothesis property tests)
# ---------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    _name = st.text(alphabet="abcdefgh-123", min_size=1, max_size=8)
    _pos_float = st.floats(min_value=1e-3, max_value=1e3, allow_nan=False,
                           allow_infinity=False)
    _device = st.builds(
        DeviceSpec, name=_name,
        onchip_bytes=st.one_of(st.none(),
                               st.integers(min_value=1, max_value=2 ** 40)),
        compute_scale=_pos_float, bandwidth_scale=_pos_float)
    _topology = st.builds(
        Topology,
        devices=st.lists(_device, min_size=1, max_size=5).map(tuple),
        name=_name)
    _spec = st.builds(
        DeploymentSpec,
        model=st.one_of(st.none(), st.just("cnn:ResNet50")),
        stages=st.one_of(st.none(), st.integers(min_value=1, max_value=64)),
        strategy=st.sampled_from(
            ("comp", "balanced", "opt", "placement", "balanced_placement")),
        objective=st.none(),
        topology=st.one_of(st.none(), _topology),
        replicate=st.booleans(),
        max_replicas=st.one_of(st.none(),
                               st.integers(min_value=1, max_value=8)),
        refine=st.one_of(st.none(), st.booleans()),
        memory_headroom_bytes=st.integers(min_value=0, max_value=2 ** 24),
        prof_batch=st.integers(min_value=1, max_value=64),
        cost_source=st.sampled_from(
            ("analytic", "trace:artifacts/t.json",
             "calibrated:artifacts/t.json")),
        max_batch=st.integers(min_value=1, max_value=256),
        max_wait_s=st.floats(min_value=0, max_value=10, allow_nan=False),
        queue_size=st.integers(min_value=1, max_value=1024),
        microbatch=st.one_of(st.none(),
                             st.integers(min_value=1, max_value=32)),
        microbatch_wait_s=st.floats(min_value=0, max_value=1,
                                    allow_nan=False),
        slo_p95_ms=st.one_of(st.none(), _pos_float),
        slo_throughput_rps=st.one_of(st.none(), _pos_float),
        max_context=st.one_of(st.none(),
                              st.integers(min_value=2, max_value=65536)),
        decode_concurrency=st.one_of(
            st.none(), st.integers(min_value=1, max_value=512)))

    @settings(max_examples=60, deadline=None)
    @given(spec=_spec)
    def test_spec_json_roundtrip_property(spec):
        doc = spec.to_json()
        back = DeploymentSpec.from_json(doc)
        assert back == spec
        # and the document is plain JSON (no repr smuggling)
        json.loads(doc)

    # the decode tier: workload="decode" is only valid with an lm: ref
    _decode_spec = st.builds(
        DeploymentSpec,
        model=st.sampled_from(("lm:qwen3-1.7b", "lm:rwkv6-1.6b",
                               "lm:qwen2.5-14b:seq=128")),
        workload=st.just("decode"),
        strategy=st.just("decode_placement"),
        stages=st.one_of(st.none(), st.integers(min_value=1, max_value=8)),
        max_context=st.one_of(st.none(),
                              st.integers(min_value=2, max_value=65536)),
        decode_concurrency=st.one_of(
            st.none(), st.integers(min_value=1, max_value=512)),
        queue_size=st.integers(min_value=1, max_value=1024))

    @settings(max_examples=60, deadline=None)
    @given(spec=_decode_spec)
    def test_decode_spec_json_roundtrip_property(spec):
        doc = spec.to_json()
        back = DeploymentSpec.from_json(doc)
        assert back == spec
        assert back.workload == "decode"
        json.loads(doc)

    _floats = st.lists(st.floats(min_value=0, max_value=1e3,
                                 allow_nan=False), max_size=5).map(tuple)
    _ints = st.lists(st.integers(min_value=0, max_value=2 ** 40),
                     max_size=5).map(tuple)
    _report = st.builds(
        PlanReport, graph_name=_name, strategy=_name,
        n_stages=st.integers(min_value=0, max_value=16),
        n_devices=st.integers(min_value=0, max_value=32),
        stage_times_s=_floats, effective_stage_times_s=_floats,
        max_stage_time_s=st.floats(min_value=0, max_value=10,
                                   allow_nan=False),
        bottleneck_stage=st.integers(min_value=-1, max_value=15),
        imbalance_time_pct=st.floats(min_value=0, max_value=100,
                                     allow_nan=False),
        stage_params=_ints, imbalance_params=st.integers(min_value=0),
        stage_device_bytes=_ints, stage_host_bytes=_ints,
        stage_capacity_bytes=_ints, spill_bytes=st.integers(min_value=0),
        devices=st.lists(_name, max_size=5).map(tuple),
        replicas=st.lists(st.integers(min_value=1, max_value=8),
                          max_size=5).map(tuple),
        decode_tokens_per_s=st.floats(min_value=0, max_value=1e9,
                                      allow_nan=False),
        decode_concurrency=st.integers(min_value=0, max_value=512),
        decode_max_context=st.integers(min_value=0, max_value=65536),
        stage_kv_bytes=_ints, stage_kv_cap_bytes=_ints,
        kv_headroom_pct=st.floats(min_value=-1, max_value=100,
                                  allow_nan=False))

    @settings(max_examples=60, deadline=None)
    @given(report=_report)
    def test_report_json_roundtrip_property(report):
        assert PlanReport.from_json(report.to_json()) == report

    # a member spec must leave its device shape to the pool-split solver
    _member_deploy_spec = st.builds(
        DeploymentSpec,
        model=st.sampled_from(("cnn:ResNet50", "synthetic-cnn:8")),
        strategy=st.sampled_from(("balanced", "placement")),
        deadline_ms=st.one_of(st.none(), _pos_float),
        max_batch=st.integers(min_value=1, max_value=256),
        slo_p95_ms=st.one_of(st.none(), _pos_float),
        slo_throughput_rps=st.one_of(st.none(), _pos_float))

    @st.composite
    def _fleet_specs(draw):
        n = draw(st.integers(min_value=1, max_value=4))
        members = tuple(
            FleetMemberSpec(
                name=f"m{i}",
                spec=draw(_member_deploy_spec),
                share=draw(st.floats(min_value=0.1, max_value=16,
                                     allow_nan=False)),
                min_devices=draw(st.integers(min_value=1, max_value=2)),
                max_devices=draw(st.one_of(
                    st.none(), st.integers(min_value=2, max_value=8))))
            for i in range(n))
        floor = sum(m.min_devices for m in members)
        # either a partitioned pool that fits every floor, or a pool
        # smaller than the member count (the time-sliced fallback,
        # where per-member floors do not apply)
        pools = [st.integers(min_value=floor, max_value=floor + 8)]
        if n > 1:
            pools.append(st.integers(min_value=1, max_value=n - 1))
        budget = draw(st.one_of(*pools))
        return FleetSpec(
            members=members, device_budget=budget,
            rebalance_cooldown_windows=draw(
                st.integers(min_value=0, max_value=8)),
            rebalance_headroom=draw(
                st.floats(min_value=0.5, max_value=4, allow_nan=False)))

    @settings(max_examples=60, deadline=None)
    @given(fleet=_fleet_specs())
    def test_fleet_spec_json_roundtrip_property(fleet):
        doc = fleet.to_json()
        assert FleetSpec.from_json(doc) == fleet
        json.loads(doc)
else:
    @pytest.mark.skip(reason="property tests need hypothesis "
                             "(pip install -r requirements-dev.txt)")
    def test_json_roundtrip_properties():
        pass


def test_plan_json_carries_report():
    g = REAL_CNNS["MobileNet"]().to_layer_graph()
    pl = plan(DeploymentSpec(stages=3, strategy="opt"), graph=g)
    assert pl.report is not None
    back = PlacementPlan.from_json(pl.to_json())
    assert back.report == pl.report
    # legacy documents (no report key) still load
    doc = json.loads(pl.to_json())
    doc.pop("report")
    assert PlacementPlan.from_json(json.dumps(doc)).report is None


# ---------------------------------------------------------------------------
# neutral edge-case records (satellite)
# ---------------------------------------------------------------------------
def test_latency_percentiles_empty_is_neutral():
    rec = latency_percentiles([])
    assert rec == {"n": 0, "p50_s": 0.0, "p95_s": 0.0, "p99_s": 0.0,
                   "mean_s": 0.0, "max_s": 0.0}


def test_plan_report_single_stage_is_neutral():
    g = toy_graph(4)
    pl = plan(DeploymentSpec(stages=1, strategy="balanced_norefine"),
              graph=g)
    rep = pl.report
    assert rep.n_stages == 1
    assert rep.imbalance_params == 0
    assert rep.imbalance_time_pct == 0.0
    assert rep.bottleneck_stage == 0
    assert rep.max_stage_time_s == pl.stage_times_s[0]
    rep.describe()                                   # doesn't raise


def test_plan_report_empty_plan_is_neutral():
    empty = PlacementPlan(graph_name="none", strategy="manual", stages=[])
    rep = PlanReport.from_plan(empty)
    assert rep.n_stages == 0 and rep.bottleneck_stage == -1
    assert rep.max_stage_time_s == 0.0 and rep.spill_bytes == 0
    assert "no modeled times" in rep.describe()


# ---------------------------------------------------------------------------
# removed legacy entry points (ISSUE 5 satellite: shims deleted, stubs
# raise with the migration pointer)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("entry,args", [
    ("plan", lambda g: (g, 2, "comp")),
    ("plan_placement", lambda g: (g, Topology.homogeneous(2))),
    ("plan_summary_table", lambda g: (g, 2)),
])
def test_removed_entry_points_raise_with_pointer(entry, args):
    g = toy_graph()
    stub = getattr(legacy, entry)
    with pytest.raises(RuntimeError, match="repro.api"):
        stub(*args(g))
    with pytest.raises(RuntimeError, match=entry):
        stub(*args(g))


def test_removed_entry_points_not_reexported_from_core():
    """The planner shim re-exports nothing: repro.core no longer carries
    the removed legacy callables, the plan types resolve to their
    canonical home (repro.core.placement), and asking the shim for a
    moved type points at it."""
    import repro.core as core
    for entry in ("plan", "plan_placement", "plan_summary_table"):
        assert not hasattr(core, entry)
        assert entry not in core.__all__
    from repro.core.placement import PlacementPlan as canonical
    assert core.PlacementPlan is canonical
    with pytest.raises(AttributeError, match="repro.core.placement"):
        legacy.PlacementPlan


def test_front_door_emits_no_deprecation_warnings():
    """The repo's own surface (api, benchmarks, examples, ElasticPlanner)
    is fully off the removed entry points: planning through the front
    door emits no DeprecationWarning (CI also runs the whole suite under
    -W error::DeprecationWarning)."""
    g = toy_graph()
    from repro.runtime import ElasticPlanner
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        plan(DeploymentSpec(stages=2, strategy="opt"), graph=g)
        plan(DeploymentSpec(strategy="placement", device_budget=3),
             graph=g)
        ElasticPlanner(g, "balanced_norefine").plan_for(2)
        from repro.core.placement import min_stages_no_spill
        min_stages_no_spill(g)                   # helper was kept (moved)


# ---------------------------------------------------------------------------
# Deployment handle
# ---------------------------------------------------------------------------
def _stage_fn_builder(p):
    return [lambda x, i=i: x + 10 ** i for i in range(p.n_stages)]


def test_deploy_executor_runs_plan():
    dep = deploy(DeploymentSpec(stages=3, strategy="balanced_norefine"),
                 graph=toy_graph(), stage_fn_builder=_stage_fn_builder)
    with dep.executor() as ex:
        outs, _ = ex.run_batch([0, 1])
    assert outs == [111, 112]


def test_deploy_serve_and_reconfigure_hot_swap():
    dep = deploy(DeploymentSpec(stages=3, strategy="balanced_norefine",
                                max_batch=4, max_wait_s=0.01),
                 graph=toy_graph(), stage_fn_builder=_stage_fn_builder)
    with dep:
        srv = dep.serve()
        assert srv.plan is dep.plan
        assert srv.serve_batch([0, 1]) == [111, 112]
        new_plan = dep.reconfigure(stages=2)          # a device left
        assert new_plan.n_stages == 2
        assert dep.spec.stages == 2
        assert srv.serve_batch([0]) == [11]           # served by new plan
    assert dep.server is None                         # closed


def test_deploy_reconfigure_with_full_spec():
    dep = deploy(DeploymentSpec(stages=2, strategy="balanced_norefine"),
                 graph=toy_graph(), stage_fn_builder=_stage_fn_builder)
    new = dep.reconfigure(DeploymentSpec(stages=3, strategy="comp"))
    assert new.n_stages == 3 and dep.plan.strategy == "comp"
    with pytest.raises(ValueError, match="exactly one"):
        dep.reconfigure()
    with pytest.raises(ValueError, match="exactly one"):
        dep.reconfigure(DeploymentSpec(stages=2), stages=2)


def test_from_plan_derives_reconfigurable_spec():
    g = toy_graph(8)
    # hand-built strategy tag -> balanced resizes (documented fallback)
    hand = PlacementPlan.from_cuts(g, [3], strategy="replicated",
                                   replicas=[2, 1])
    dep = Deployment.from_plan(hand, graph=g,
                               stage_fn_builder=_stage_fn_builder)
    assert dep.spec.strategy == "balanced"
    assert dep.reconfigure(stages=3).n_stages == 3
    # placement tag -> device_budget spec sized to the plan's devices
    placed = plan(DeploymentSpec(strategy="placement", device_budget=3),
                  graph=g)
    dep2 = Deployment.from_plan(placed, graph=g,
                                stage_fn_builder=_stage_fn_builder)
    assert dep2.spec.device_budget == placed.n_devices
    assert dep2.reconfigure(stages=2).n_devices <= 2


def test_reconfigure_scale_down_then_up_restores_devices():
    """Resizes derive from the original spec: truncating a topology on
    scale-down must not cap a later scale-up."""
    topo = Topology(devices=(DeviceSpec(name="a"), DeviceSpec(name="b"),
                             DeviceSpec(name="c"), DeviceSpec(name="d")))
    dep = deploy(DeploymentSpec(strategy="placement", topology=topo,
                                replicate=False), graph=toy_graph(10),
                 stage_fn_builder=_stage_fn_builder)
    assert dep.reconfigure(stages=3).n_devices == 3
    assert dep.reconfigure(stages=4).n_devices == 4     # device rejoined
    assert [d.name for d in dep.spec.topology.devices] \
        == ["a", "b", "c", "d"]


def test_externally_stopped_server_is_not_live():
    """Stopping the server through its own context manager (the benchmark
    idiom) must free the deployment: serve() works again and
    reconfigure() does not hot-swap a dead server."""
    dep = deploy(DeploymentSpec(stages=2, strategy="balanced_norefine"),
                 graph=toy_graph(), stage_fn_builder=_stage_fn_builder)
    with dep.serve() as srv:
        assert srv.serve_batch([0]) == [11]
    assert dep.server is None                 # stopped behind our back
    dep.reconfigure(stages=3)                 # replans only, no dead swap
    assert srv.executor.started is False
    srv2 = dep.serve()                        # no spurious "live server"
    assert srv2.serve_batch([0]) == [111]
    dep.close()


def test_placement_rejects_uncomposable_refine():
    with pytest.raises(ValueError, match="refine"):
        plan(DeploymentSpec(strategy="placement", device_budget=3,
                            refine=True), graph=toy_graph(10))


def test_headroom_exceeding_capacity_fails_fast():
    with pytest.raises(ValueError, match="headroom"):
        plan(DeploymentSpec(stages=2, strategy="balanced",
                            memory_headroom_bytes=1 << 40),
             graph=toy_graph())


def test_deployment_from_plan_and_fixed_fns():
    g = toy_graph()
    pl = plan(DeploymentSpec(stages=2, strategy="comp"), graph=g)
    dep = Deployment.from_plan(pl, graph=g,
                               stage_fns=[lambda x: x + 1,
                                          lambda x: x * 2])
    assert dep.spec.stages == 2
    with dep.executor() as ex:
        outs, _ = ex.run_batch([1, 2])
    assert outs == [4, 6]
    # fixed fns cannot follow a resize
    with pytest.raises(ValueError, match="stage_fn_builder"):
        dep.stage_functions(plan(DeploymentSpec(stages=3, strategy="comp"),
                                 graph=g))


def test_deployment_requires_stage_functions():
    dep = deploy(DeploymentSpec(stages=2, strategy="comp"),
                 graph=toy_graph())
    with pytest.raises(ValueError, match="no stage functions"):
        dep.executor()


def test_serve_twice_requires_server_stop():
    dep = deploy(DeploymentSpec(stages=2, strategy="comp"),
                 graph=toy_graph(), stage_fn_builder=_stage_fn_builder)
    with dep:
        srv = dep.serve()
        with pytest.raises(RuntimeError, match="live server"):
            dep.serve()
        srv.stop()
        dep.serve()               # stopping the server frees the slot


def test_close_is_terminal_and_idempotent():
    dep = deploy(DeploymentSpec(stages=2, strategy="comp"),
                 graph=toy_graph(), stage_fn_builder=_stage_fn_builder)
    dep.serve()
    dep.close()
    assert dep.closed
    dep.close()                   # idempotent: a second close is a no-op
    assert dep.closed
    for call in (dep.serve, dep.executor,
                 lambda: dep.reconfigure(stages=3)):
        with pytest.raises(RuntimeError, match="closed"):
            call()


def test_closed_deployment_rejects_with_reentry():
    dep = deploy(DeploymentSpec(stages=2, strategy="comp"),
                 graph=toy_graph(), stage_fn_builder=_stage_fn_builder)
    with dep:
        pass
    with pytest.raises(RuntimeError, match="closed"):
        with dep:
            pass
