"""Shared test helpers.

``api_plan`` is the :mod:`repro.api` front door exposed with the legacy
positional call shape most tests were written against — every test plans
through the strategy registry (no DeprecationWarnings anywhere in the
suite; CI runs a ``-W error::DeprecationWarning`` leg to prove it).  The
removed ``repro.core.planner`` entry points are exercised only by the
raises-with-pointer tests in tests/test_deploy_api.py.
"""
from repro.api import DeploymentSpec
from repro.api import plan as _front_door_plan


def api_plan(graph, n_stages, strategy="balanced", reporter=None,
             tpu_model=None, **spec_kw):
    """plan(graph, n, strategy, ...) in the legacy shape, via repro.api."""
    return _front_door_plan(
        DeploymentSpec(stages=n_stages, strategy=strategy, **spec_kw),
        graph=graph, tpu_model=tpu_model, reporter=reporter)


def api_plan_placement(graph, topology, strategy="opt", replicate=True,
                       max_replicas=None, base_spec=None):
    """plan_placement(...) in the legacy shape, via repro.api."""
    name = "placement" if strategy == "opt" else "balanced_placement"
    return _front_door_plan(
        DeploymentSpec(strategy=name, topology=topology,
                       replicate=replicate, max_replicas=max_replicas),
        graph=graph, base_spec=base_spec)
