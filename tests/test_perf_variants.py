"""Tests for the perf-loop machinery: chunked WKV equivalence, variant
knobs, cache sharding modes, cost-balanced planning, tie-breaks."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.launch import variants
from repro.models.rwkv6 import wkv_chunked, wkv_step


def _scan_ref(r, k, v, w, u, s0):
    def body(st, xs):
        r_t, k_t, v_t, w_t = xs
        st, y = wkv_step(st, r_t, k_t, v_t, w_t, u)
        return st, y

    xs = tuple(x.transpose(1, 0, 2, 3) for x in (r, k, v, w))
    st, ys = jax.lax.scan(body, s0, xs)
    return ys.transpose(1, 0, 2, 3), st


@pytest.mark.parametrize("chunk", [16, 32, 64, 128])
def test_wkv_chunked_equals_scan(chunk):
    rng = np.random.default_rng(1)
    B, S, H, D = 2, 128, 4, 16
    r = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.85, 0.9999, (B, S, H, D)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, D)) * 0.2, jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(B, H, D, D)) * 0.1, jnp.float32)
    yr, sr = _scan_ref(r, k, v, w, u, s0)
    y, s = wkv_chunked(r, k, v, w, u, s0, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr),
                               rtol=2e-4, atol=2e-4)


@given(st.integers(0, 2 ** 16))
@settings(max_examples=15, deadline=None)
def test_wkv_chunked_property(seed):
    rng = np.random.default_rng(seed)
    B, S, H, D = 1, 64, 2, 8
    r = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, D)) * 0.3, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, D)), jnp.float32)
    w = jnp.asarray(rng.uniform(0.8, 1.0, (B, S, H, D)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, D)) * 0.2, jnp.float32)
    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    yr, sr = _scan_ref(r, k, v, w, u, s0)
    y, s = wkv_chunked(r, k, v, w, u, s0, chunk=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=3e-4, atol=3e-4)


def test_variant_knobs_parse(monkeypatch):
    monkeypatch.setenv("REPRO_VARIANT", "cache_hd, rwkv_scan")
    assert variants.on("cache_hd")
    assert variants.on("rwkv_scan")
    assert not variants.on("no_fsdp")
    monkeypatch.setenv("REPRO_VARIANT", "baseline")
    assert not variants.active()


def test_rwkv_scan_knob_reverts_to_per_token(monkeypatch):
    """Forward must be identical under both WKV implementations."""
    from repro import configs
    from repro.configs.common import concrete_batch
    from repro.models import api
    cfg = configs.get("rwkv6-1.6b").smoke_config()
    params = api.init(cfg, jax.random.PRNGKey(0))
    batch = concrete_batch(cfg, 32, 2, kind="prefill")
    monkeypatch.setenv("REPRO_VARIANT", "")
    chunked = api.forward(cfg, params, batch)
    monkeypatch.setenv("REPRO_VARIANT", "rwkv_scan")
    scanned = api.forward(cfg, params, batch)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(scanned),
                               rtol=2e-3, atol=2e-3)


def test_cache_shardings_modes():
    from repro.launch.mesh import make_mesh
    from repro.launch.sharding import cache_shardings
    import subprocess, sys, textwrap, os as _os
    env = dict(_os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _os.path.join(
        _os.path.dirname(_os.path.dirname(_os.path.abspath(__file__))),
        "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.launch.mesh import make_mesh
        from repro.launch.sharding import cache_shardings
        mesh = make_mesh((2, 4), ("data", "model"))
        cache = {"k": jax.ShapeDtypeStruct((4, 8, 64, 2, 16), jnp.bfloat16),
                 "v": jax.ShapeDtypeStruct((4, 8, 64, 2, 16), jnp.bfloat16),
                 "len": jax.ShapeDtypeStruct((), jnp.int32)}
        hd = cache_shardings(mesh, cache, mode="hd")
        sq = cache_shardings(mesh, cache, mode="seq")
        assert hd["k"].spec == jax.sharding.PartitionSpec(
            None, "data", None, None, "model"), hd["k"].spec
        assert sq["k"].spec == jax.sharding.PartitionSpec(
            None, "data", "model", None, None), sq["k"].spec
        assert sq["len"].spec == jax.sharding.PartitionSpec()
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


def test_balanced_cost_strategy_reduces_stage_time():
    """Beyond-paper: cost-weighted balance beats params balance on a model
    whose MAC intensity varies with depth (high-res early CNN layers)."""
    from conftest import api_plan as plan
    from repro.core import EdgeTPUModel
    from repro.core.placement import min_stages_no_spill
    from repro.models.cnn import REAL_CNNS
    g = REAL_CNNS["ResNet152"]().to_layer_graph()
    m = EdgeTPUModel(g)
    n = min_stages_no_spill(g, m)
    t_params = max(m.stage_times(plan(g, n, "balanced", tpu_model=m).cuts))
    t_cost = max(m.stage_times(plan(g, n, "balanced_cost",
                                    tpu_model=m).cuts))
    assert t_cost <= t_params * 1.001


def test_late_heavy_tie_break():
    """Among minimax-optimal splits, weight should sit late (the last
    pipeline stage has no output transfer)."""
    from repro.core.segmentation import balanced_split, segment_sums
    P = [10, 100, 100, 100, 100]
    late = balanced_split(P, 2, tie_break="late")
    early = balanced_split(P, 2, tie_break="early")
    assert max(segment_sums(P, late)) == max(segment_sums(P, early))
    # late variant's final segment is at least as heavy
    assert segment_sums(P, late)[-1] >= segment_sums(P, early)[-1]
